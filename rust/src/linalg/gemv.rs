//! BLAS-2 matvec kernels over the column-major [`Mat`].
//!
//! Two orientations, each with a full-matrix and an active-set variant:
//!
//! * [`gemv`]   — `out = A x`   (column-major ⇒ accumulate `x_j · a_j`;
//!   skipping `x_j = 0` makes the cost proportional to the support, which
//!   is exactly what screening buys).
//! * [`gemv_t`] — `out = Aᵀ r`  (one contiguous dot per column).
//!
//! The active-set variants (`*_cols`) touch only the listed columns —
//! the native backend's physical counterpart of the masked PJRT graphs.

use super::vec_ops::dot;
use super::Mat;

/// out = A x (dense x).  Zero entries of `x` are skipped, so the cost is
/// `2 m · nnz(x)` flops.
pub fn gemv(a: &Mat, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), a.cols(), "gemv: x length");
    assert_eq!(out.len(), a.rows(), "gemv: out length");
    out.fill(0.0);
    for (j, &xj) in x.iter().enumerate() {
        if xj != 0.0 {
            let col = a.col(j);
            for (o, &c) in out.iter_mut().zip(col) {
                *o += xj * c;
            }
        }
    }
}

/// out = Aᵀ r: one dot product per column.
pub fn gemv_t(a: &Mat, r: &[f64], out: &mut [f64]) {
    assert_eq!(r.len(), a.rows(), "gemv_t: r length");
    assert_eq!(out.len(), a.cols(), "gemv_t: out length");
    for j in 0..a.cols() {
        out[j] = dot(a.col(j), r);
    }
}

/// out = A x restricted to `active` columns; `x` is indexed by *position
/// in `active`* (compact representation).
pub fn gemv_cols(a: &Mat, active: &[usize], x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), active.len(), "gemv_cols: x length");
    assert_eq!(out.len(), a.rows(), "gemv_cols: out length");
    out.fill(0.0);
    for (k, &j) in active.iter().enumerate() {
        let xk = x[k];
        if xk != 0.0 {
            let col = a.col(j);
            for (o, &c) in out.iter_mut().zip(col) {
                *o += xk * c;
            }
        }
    }
}

/// out[k] = ⟨a_{active[k]}, r⟩ (compact Aᵀ r over the active set).
pub fn gemv_t_cols(a: &Mat, active: &[usize], r: &[f64], out: &mut [f64]) {
    assert_eq!(out.len(), active.len(), "gemv_t_cols: out length");
    assert_eq!(r.len(), a.rows(), "gemv_t_cols: r length");
    for (k, &j) in active.iter().enumerate() {
        out[k] = dot(a.col(j), r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, m: usize, n: usize) -> Mat {
        let mut mat = Mat::zeros(m, n);
        for j in 0..n {
            for i in 0..m {
                mat.set(i, j, rng.normal());
            }
        }
        mat
    }

    fn naive_gemv(a: &Mat, x: &[f64]) -> Vec<f64> {
        (0..a.rows())
            .map(|i| (0..a.cols()).map(|j| a.get(i, j) * x[j]).sum())
            .collect()
    }

    fn naive_gemv_t(a: &Mat, r: &[f64]) -> Vec<f64> {
        (0..a.cols())
            .map(|j| (0..a.rows()).map(|i| a.get(i, j) * r[i]).sum())
            .collect()
    }

    #[test]
    fn gemv_matches_naive() {
        let mut rng = Pcg64::new(0);
        for (m, n) in [(1, 1), (3, 7), (17, 33), (100, 50)] {
            let a = rand_mat(&mut rng, m, n);
            let mut x = vec![0.0; n];
            rng.fill_normal(&mut x);
            let mut out = vec![0.0; m];
            gemv(&a, &x, &mut out);
            let want = naive_gemv(&a, &x);
            for (g, w) in out.iter().zip(&want) {
                assert!((g - w).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemv_t_matches_naive() {
        let mut rng = Pcg64::new(1);
        for (m, n) in [(1, 1), (5, 2), (31, 64), (100, 500)] {
            let a = rand_mat(&mut rng, m, n);
            let mut r = vec![0.0; m];
            rng.fill_normal(&mut r);
            let mut out = vec![0.0; n];
            gemv_t(&a, &r, &mut out);
            let want = naive_gemv_t(&a, &r);
            for (g, w) in out.iter().zip(&want) {
                assert!((g - w).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn gemv_skips_zeros_consistently() {
        let mut rng = Pcg64::new(2);
        let a = rand_mat(&mut rng, 20, 40);
        let mut x = vec![0.0; 40];
        // sparse x
        for k in [3usize, 17, 39] {
            x[k] = rng.normal();
        }
        let mut out = vec![0.0; 20];
        gemv(&a, &x, &mut out);
        let want = naive_gemv(&a, &x);
        for (g, w) in out.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn active_set_variants_match_full() {
        let mut rng = Pcg64::new(3);
        let a = rand_mat(&mut rng, 15, 30);
        let active = vec![2usize, 5, 11, 29];
        let xc: Vec<f64> = (0..active.len()).map(|_| rng.normal()).collect();

        // gemv_cols == gemv with scattered x
        let mut x_full = vec![0.0; 30];
        for (k, &j) in active.iter().enumerate() {
            x_full[j] = xc[k];
        }
        let mut out_c = vec![0.0; 15];
        let mut out_f = vec![0.0; 15];
        gemv_cols(&a, &active, &xc, &mut out_c);
        gemv(&a, &x_full, &mut out_f);
        for (c, f) in out_c.iter().zip(&out_f) {
            assert!((c - f).abs() < 1e-12);
        }

        // gemv_t_cols == gather(gemv_t)
        let mut r = vec![0.0; 15];
        rng.fill_normal(&mut r);
        let mut full = vec![0.0; 30];
        gemv_t(&a, &r, &mut full);
        let mut compact = vec![0.0; active.len()];
        gemv_t_cols(&a, &active, &r, &mut compact);
        for (k, &j) in active.iter().enumerate() {
            assert!((compact[k] - full[j]).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn gemv_shape_mismatch_panics() {
        let a = Mat::zeros(3, 4);
        let mut out = vec![0.0; 3];
        gemv(&a, &[1.0; 5], &mut out);
    }
}
