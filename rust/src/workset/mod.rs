//! The physically compacted working set — contiguous storage for the
//! atoms that survive screening, plus the policy deciding *when* the
//! copy is worth it.
//!
//! ## Why
//!
//! Screening shrinks the active set fast (the whole point of the
//! paper's Hölder dome), but a solver that keeps *gathering* the
//! surviving columns by index out of the full `m × n` dictionary
//! streams scattered, prefetch-hostile memory on every iteration.
//! Once 90% of the atoms are gone, the per-iteration matvecs touch
//! only ~10% of the matrix — spread across all of it.  Materializing
//! the survivors into a contiguous [`Mat`] costs one `O(m·k)` copy and
//! turns every subsequent matvec into a pure sequential stream
//! ([`crate::linalg::gemv_compact_sharded`],
//! [`crate::linalg::gemv_t_blocked_sharded`]).
//!
//! ## Lifecycle (screen → retain → compact → blocked kernels)
//!
//! 1. The solver screens and calls `ScreeningState::retain`.
//! 2. [`WorkingSet::on_retain`] updates its column map; while the
//!    storage is stale it keeps *gathering* — out of the compact store
//!    if one exists (already a smaller footprint), else out of the
//!    full dictionary.
//! 3. When the fraction of columns removed since the last rebuild
//!    exceeds the [`CompactionPolicy`] threshold, the survivors are
//!    physically re-materialized (columns, `‖a_i‖` and `(Aᵀy)_i`
//!    caches), and the index indirection disappears.
//! 4. Contiguous storage enables the cache-blocked kernels until the
//!    next rebuild.
//!
//! ## Storage formats: the `SparseStore` variant
//!
//! The working set mirrors the problem's [`DictStore`] backend.  For a
//! dense dictionary the compact storage is a contiguous [`Mat`]; for a
//! CSC dictionary it is a compact [`CscMat`] whose rebuild gathers the
//! surviving columns' nonzero runs into contiguous `(row_idx, val)`
//! storage ([`CscMat::select_columns_into`]) — same
//! [`CompactionPolicy`] contract, same gather-vs-contiguous dispatch,
//! sparse kernels ([`crate::linalg::spmv`]) instead of dense ones.
//! Because those kernels replay the dense per-element operation order,
//! the storage format is bitwise invisible in the `SolveReport` too.
//!
//! ## Determinism
//!
//! Compaction never changes results: compact columns are bit-exact
//! copies, the compact kernels accumulate in the exact sequential
//! operation order of their gather counterparts, and the flop meter is
//! charged identically (the copy is pure data movement — zero flops,
//! see [`crate::flops`]).  `SolveReport`s are therefore **bitwise
//! identical** for every policy (disabled / any threshold), thread
//! count, and dictionary storage format
//! (`rust/tests/workset_parity.rs`).

use crate::flops::FlopCounter;
use crate::linalg::{self, ColView, Mat};
use crate::par::ParContext;
use crate::problem::LassoProblem;
use crate::screening::ScreeningState;
use crate::sparse::{CscMat, DictStore};

/// When to physically rebuild the compact working-set storage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompactionPolicy {
    /// Never materialize: always gather out of the full dictionary
    /// (the pre-working-set behavior; useful as a baseline).
    Disabled,
    /// Rebuild once the fraction of columns removed since the last
    /// (re)build exceeds this value.  `0.0` re-compacts after every
    /// removing round; `1.0` never re-compacts (equivalent to
    /// [`Disabled`](Self::Disabled) in all but name).  The copy is
    /// `O(m·k)` once and is amortized over the many iterations until
    /// the next screening round.
    Threshold(f64),
}

impl CompactionPolicy {
    /// Default rebuild threshold: a quarter of the working set gone
    /// since the last build.  Low enough that the blocked kernels see
    /// mostly-contiguous storage, high enough that rebuild copies stay
    /// rare.
    pub const DEFAULT_THRESHOLD: f64 = 0.25;

    /// CLI adapter: negative values disable compaction.
    pub fn from_threshold(t: f64) -> CompactionPolicy {
        if t < 0.0 {
            CompactionPolicy::Disabled
        } else {
            CompactionPolicy::Threshold(t)
        }
    }
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        CompactionPolicy::Threshold(Self::DEFAULT_THRESHOLD)
    }
}

/// Physically compacted storage in the dictionary's format: a
/// contiguous dense [`Mat`], or the `SparseStore` variant — a compact
/// [`CscMat`] holding the surviving columns' `(row_idx, val)` runs.
#[derive(Clone, Debug)]
enum CompactStore {
    Dense(Mat),
    Sparse(CscMat),
}

impl Default for CompactStore {
    fn default() -> Self {
        CompactStore::Dense(Mat::default())
    }
}

/// Contiguous storage + scratch for one solve's surviving atoms.
///
/// Owned by the solver loop (or reused across a λ-path's solves — the
/// buffers shrink monotonically within a solve and are recycled by
/// [`reset`](Self::reset)), and threaded through the solvers' metered
/// evaluation and the [`crate::screening::ScreeningEngine`].
#[derive(Clone, Debug, Default)]
pub struct WorkingSet {
    policy: CompactionPolicy,
    /// Compact column storage (dense or sparse, mirroring the
    /// problem's [`DictStore`]); meaningful only while `live`.
    a_c: CompactStore,
    /// `‖a_i‖` for each *current* active position (compacted on every
    /// retain while live).
    norms_c: Vec<f64>,
    /// `(Aᵀy)_i` for each current active position (ditto).
    aty_c: Vec<f64>,
    /// Stored-structure nonzeros for each current active position
    /// (ditto) — the flop meter's matvec weights.
    nnz_c: Vec<usize>,
    /// Column of `a_c` holding the atom at each current active
    /// position; identity right after a rebuild.
    pos: Vec<usize>,
    /// Storage has been materialized at least once this solve.
    live: bool,
    /// `pos` is the identity — the blocked/compact kernels apply.
    contiguous: bool,
    /// Active-column count at the last (re)build (or solve start).
    cols_at_build: usize,
    /// Physical rebuilds performed over this value's lifetime.
    rebuilds: usize,
    /// Reusable (column, coefficient) scratch for the row-sharded `Ax`.
    nz: Vec<(usize, f64)>,
    /// Reusable scaled-dual buffer (`u = s·r`, one per screening round).
    u: Vec<f64>,
}

impl WorkingSet {
    /// A working set for a fresh solve over `n` atoms.
    pub fn new(policy: CompactionPolicy, n: usize) -> Self {
        WorkingSet { policy, cols_at_build: n, ..Default::default() }
    }

    /// A permanently-gathering working set (used where no compaction
    /// context exists, e.g. standalone screening-engine calls).
    pub fn gather_only() -> Self {
        Self::new(CompactionPolicy::Disabled, 0)
    }

    /// Recycle for another solve over `n` atoms (λ-path carry-over:
    /// the heap buffers — compact matrix, caches, scratch — keep their
    /// capacity).
    pub fn reset(&mut self, n: usize) {
        self.live = false;
        self.contiguous = false;
        self.pos.clear();
        self.cols_at_build = n;
    }

    pub fn policy(&self) -> CompactionPolicy {
        self.policy
    }

    /// Has the compact storage been materialized this solve?
    pub fn is_live(&self) -> bool {
        self.live
    }

    /// Is the storage physically contiguous (blocked kernels active)?
    pub fn is_contiguous(&self) -> bool {
        self.contiguous
    }

    /// Physical rebuilds performed so far (diagnostics).
    pub fn rebuilds(&self) -> usize {
        self.rebuilds
    }

    /// `out = A x` over the active set (`x` compact, aligned with
    /// `active`).  Dispatches to the contiguous, compact-gather or
    /// full-gather kernel; all three are bitwise identical.
    pub fn gemv(
        &mut self,
        p: &LassoProblem,
        active: &[usize],
        x: &[f64],
        out: &mut [f64],
        ctx: &ParContext,
    ) {
        assert_eq!(x.len(), active.len(), "WorkingSet::gemv: x length");
        if self.live {
            debug_assert_eq!(self.pos.len(), active.len());
            match (&self.a_c, self.contiguous) {
                (CompactStore::Dense(a), true) => {
                    linalg::gemv_compact_sharded(
                        a, x, out, ctx, &mut self.nz,
                    );
                }
                (CompactStore::Dense(a), false) => {
                    linalg::gemv_cols_sharded_scratch(
                        a, &self.pos, x, out, ctx, &mut self.nz,
                    );
                }
                (CompactStore::Sparse(a), true) => {
                    linalg::spmv_compact_sharded(
                        a, x, out, ctx, &mut self.nz,
                    );
                }
                (CompactStore::Sparse(a), false) => {
                    linalg::spmv_cols_sharded_scratch(
                        a, &self.pos, x, out, ctx, &mut self.nz,
                    );
                }
            }
        } else {
            match p.store() {
                DictStore::Dense(a) => linalg::gemv_cols_sharded_scratch(
                    a, active, x, out, ctx, &mut self.nz,
                ),
                DictStore::Csc(a) => linalg::spmv_cols_sharded_scratch(
                    a, active, x, out, ctx, &mut self.nz,
                ),
            }
        }
    }

    /// `out[k] = ⟨a_{active[k]}, r⟩` over the active set.  Contiguous
    /// storage uses the cache-blocked kernel; results are bitwise
    /// identical either way.
    pub fn gemv_t(
        &self,
        p: &LassoProblem,
        active: &[usize],
        r: &[f64],
        out: &mut [f64],
        ctx: &ParContext,
    ) {
        assert_eq!(out.len(), active.len(), "WorkingSet::gemv_t: out length");
        if self.live {
            debug_assert_eq!(self.pos.len(), active.len());
            match (&self.a_c, self.contiguous) {
                (CompactStore::Dense(a), true) => {
                    linalg::gemv_t_blocked_sharded(a, r, out, ctx);
                }
                (CompactStore::Dense(a), false) => {
                    linalg::gemv_t_cols_sharded(a, &self.pos, r, out, ctx);
                }
                (CompactStore::Sparse(a), true) => {
                    linalg::spmv_t_compact_sharded(a, r, out, ctx);
                }
                (CompactStore::Sparse(a), false) => {
                    linalg::spmv_t_cols_sharded(a, &self.pos, r, out, ctx);
                }
            }
        } else {
            match p.store() {
                DictStore::Dense(a) => {
                    linalg::gemv_t_cols_sharded(a, active, r, out, ctx);
                }
                DictStore::Csc(a) => {
                    linalg::spmv_t_cols_sharded(a, active, r, out, ctx);
                }
            }
        }
    }

    /// The atom column at active position `k` in either storage format
    /// (CD's inner loop — [`ColView`] replays the dense per-column
    /// primitives bitwise).
    pub fn col_view<'a>(
        &'a self,
        p: &'a LassoProblem,
        active: &[usize],
        k: usize,
    ) -> ColView<'a> {
        if self.live {
            match &self.a_c {
                CompactStore::Dense(a) => ColView::Dense(a.col(self.pos[k])),
                CompactStore::Sparse(a) => {
                    let (rows, vals) = a.col(self.pos[k]);
                    ColView::Sparse { rows, vals }
                }
            }
        } else {
            match p.store() {
                DictStore::Dense(a) => ColView::Dense(a.col(active[k])),
                DictStore::Csc(a) => {
                    let (rows, vals) = a.col(active[k]);
                    ColView::Sparse { rows, vals }
                }
            }
        }
    }

    /// The atom column at active position `k` as a dense slice.
    /// Panics for sparse-backed problems — dispatch-agnostic callers
    /// use [`col_view`](Self::col_view).
    pub fn col<'a>(
        &'a self,
        p: &'a LassoProblem,
        active: &[usize],
        k: usize,
    ) -> &'a [f64] {
        match self.col_view(p, active, k) {
            ColView::Dense(c) => c,
            ColView::Sparse { .. } => panic!(
                "WorkingSet::col: dense storage required; use col_view"
            ),
        }
    }

    /// `‖a_i‖` for the atom at active position `k`.
    pub fn col_norm(
        &self,
        p: &LassoProblem,
        active: &[usize],
        k: usize,
    ) -> f64 {
        if self.live {
            self.norms_c[k]
        } else {
            p.col_norms()[active[k]]
        }
    }

    /// Stored-structure nonzeros of the atom at active position `k`
    /// (the flop meter's per-column matvec weight; equal to `m` for a
    /// dense column with no explicit zeros).
    pub fn col_nnz(
        &self,
        p: &LassoProblem,
        active: &[usize],
        k: usize,
    ) -> usize {
        if self.live {
            self.nnz_c[k]
        } else {
            p.col_nnz()[active[k]]
        }
    }

    /// Total stored nonzeros over the active set — what one `Aᵀr`
    /// matvec touches ([`crate::flops::cost::spmv`] charges `2·nnz`).
    /// Independent of compaction state and storage format.
    pub fn active_nnz(&self, p: &LassoProblem, active: &[usize]) -> u64 {
        if self.live {
            self.nnz_c.iter().map(|&c| c as u64).sum()
        } else {
            active.iter().map(|&j| p.col_nnz()[j] as u64).sum()
        }
    }

    /// Total stored nonzeros over the columns with a nonzero
    /// coefficient — what one `A x` matvec touches.
    pub fn support_nnz(
        &self,
        p: &LassoProblem,
        active: &[usize],
        x: &[f64],
    ) -> u64 {
        debug_assert_eq!(x.len(), active.len());
        if self.live {
            x.iter()
                .zip(&self.nnz_c)
                .filter(|(xi, _)| **xi != 0.0)
                .map(|(_, &c)| c as u64)
                .sum()
        } else {
            x.iter()
                .zip(active)
                .filter(|(xi, _)| **xi != 0.0)
                .map(|(_, &j)| p.col_nnz()[j] as u64)
                .sum()
        }
    }

    /// Position-aligned `(Aᵀy, ‖a_i‖)` caches for the screening test,
    /// when materialized — contiguous reads instead of per-atom gathers
    /// out of the full-length arrays.
    pub fn compact_stats(&self) -> Option<(&[f64], &[f64])> {
        if self.live {
            Some((&self.aty_c, &self.norms_c))
        } else {
            None
        }
    }

    /// The scaled dual point `u = s·r` in a reusable buffer (one
    /// allocation per solve instead of one per screening round);
    /// charged `m` flops exactly like the vector scale it replaces.
    pub fn scaled_dual(
        &mut self,
        r: &[f64],
        s: f64,
        flops: &mut FlopCounter,
    ) -> &[f64] {
        flops.charge(r.len() as u64);
        self.u.clear();
        self.u.extend(r.iter().map(|ri| s * ri));
        &self.u
    }

    /// Post-retain hook: `keep` is the mask just applied to `state`
    /// (indexed by *previous* active position).  Updates the column
    /// map and caches, then rebuilds the physical storage if the
    /// removed-since-build fraction clears the policy threshold.
    pub fn on_retain(
        &mut self,
        p: &LassoProblem,
        state: &ScreeningState,
        keep: &[bool],
    ) {
        let threshold = match self.policy {
            CompactionPolicy::Disabled => return,
            CompactionPolicy::Threshold(t) => t,
        };
        if self.live {
            // Keep pos / norms / aty / nnz aligned with the new active
            // positions (O(k) — negligible next to the matvecs).  The
            // f64 caches go through the same mask-compaction helper the
            // solvers use for their coefficient vectors.
            crate::screening::compact_vectors(
                keep,
                &mut [&mut self.norms_c, &mut self.aty_c],
            );
            let mut k = 0;
            self.pos.retain(|_| {
                let b = keep[k];
                k += 1;
                b
            });
            let mut k = 0;
            self.nnz_c.retain(|_| {
                let b = keep[k];
                k += 1;
                b
            });
            self.contiguous =
                self.pos.iter().enumerate().all(|(i, &c)| i == c);
        }
        let k_now = state.active_count();
        let removed = self.cols_at_build.saturating_sub(k_now);
        let frac = removed as f64 / self.cols_at_build.max(1) as f64;
        if removed > 0 && frac > threshold {
            self.rebuild(p, state);
        }
    }

    /// Materialize the current active set in the dictionary's storage
    /// format — contiguous dense columns, or the surviving columns'
    /// `(row_idx, val)` runs gathered into a compact [`CscMat`] — plus
    /// the `‖a_i‖` / `(Aᵀy)_i` / nnz caches.  Pure data movement — no
    /// flops.
    fn rebuild(&mut self, p: &LassoProblem, state: &ScreeningState) {
        let active = state.active();
        match p.store() {
            DictStore::Dense(src) => {
                if !matches!(self.a_c, CompactStore::Dense(_)) {
                    self.a_c = CompactStore::Dense(Mat::default());
                }
                let CompactStore::Dense(dst) = &mut self.a_c else {
                    unreachable!()
                };
                src.select_columns_into(active, dst);
            }
            DictStore::Csc(src) => {
                if !matches!(self.a_c, CompactStore::Sparse(_)) {
                    self.a_c = CompactStore::Sparse(CscMat::default());
                }
                let CompactStore::Sparse(dst) = &mut self.a_c else {
                    unreachable!()
                };
                src.select_columns_into(active, dst);
            }
        }
        self.norms_c.clear();
        self.aty_c.clear();
        self.nnz_c.clear();
        for &j in active {
            self.norms_c.push(p.col_norms()[j]);
            self.aty_c.push(p.aty()[j]);
            self.nnz_c.push(p.col_nnz()[j]);
        }
        self.pos.clear();
        self.pos.extend(0..active.len());
        self.live = true;
        self.contiguous = true;
        self.cols_at_build = active.len();
        self.rebuilds += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Gen;

    fn problem(seed: u64, m: usize, n: usize) -> LassoProblem {
        let mut g = Gen::for_case(seed, 0);
        let a = g.dictionary(m, n);
        let y = g.observation(m);
        let mut aty = vec![0.0; n];
        linalg::gemv_t(&a, &y, &mut aty);
        let lam = 0.5 * linalg::norm_inf(&aty).max(1e-9);
        LassoProblem::new(a, y, lam)
    }

    /// Drop every `period`-th active atom, returning the applied mask.
    fn drop_every(
        state: &mut ScreeningState,
        ws: &mut WorkingSet,
        p: &LassoProblem,
        period: usize,
    ) -> Vec<bool> {
        let keep: Vec<bool> = (0..state.active_count())
            .map(|k| k % period != 0)
            .collect();
        state.retain(&keep);
        ws.on_retain(p, state, &keep);
        keep
    }

    /// The working set's matvecs must be bitwise identical to the
    /// full-dictionary gather kernels at every lifecycle stage.
    fn assert_matvec_parity(
        ws: &mut WorkingSet,
        p: &LassoProblem,
        state: &ScreeningState,
        seed: u64,
    ) {
        let mut g = Gen::for_case(seed, 1);
        let k = state.active_count();
        let x: Vec<f64> = (0..k)
            .map(|i| if i % 3 == 0 { 0.0 } else { g.f64_in(-1.0, 1.0) })
            .collect();
        let r: Vec<f64> = (0..p.m()).map(|_| g.f64_in(-1.0, 1.0)).collect();
        let ctx = ParContext::new_pool(4, 1);

        let mut want_ax = vec![0.0; p.m()];
        linalg::gemv_cols(p.a(), state.active(), &x, &mut want_ax);
        let mut got_ax = vec![f64::NAN; p.m()];
        ws.gemv(p, state.active(), &x, &mut got_ax, &ctx);
        for (w, got) in want_ax.iter().zip(&got_ax) {
            assert_eq!(w.to_bits(), got.to_bits(), "Ax drift");
        }

        let mut want_atr = vec![0.0; k];
        linalg::gemv_t_cols(p.a(), state.active(), &r, &mut want_atr);
        let mut got_atr = vec![f64::NAN; k];
        ws.gemv_t(p, state.active(), &r, &mut got_atr, &ctx);
        for (w, got) in want_atr.iter().zip(&got_atr) {
            assert_eq!(w.to_bits(), got.to_bits(), "Atr drift");
        }

        for (kp, &j) in state.active().iter().enumerate() {
            assert_eq!(ws.col(p, state.active(), kp), p.a().col(j));
            assert_eq!(
                ws.col_norm(p, state.active(), kp).to_bits(),
                p.col_norms()[j].to_bits()
            );
        }
        if let Some((aty_c, norms_c)) = ws.compact_stats() {
            for (kp, &j) in state.active().iter().enumerate() {
                assert_eq!(aty_c[kp].to_bits(), p.aty()[j].to_bits());
                assert_eq!(norms_c[kp].to_bits(), p.col_norms()[j].to_bits());
            }
        }
    }

    #[test]
    fn policy_parsing_and_default() {
        assert_eq!(
            CompactionPolicy::from_threshold(-1.0),
            CompactionPolicy::Disabled
        );
        assert_eq!(
            CompactionPolicy::from_threshold(0.5),
            CompactionPolicy::Threshold(0.5)
        );
        assert_eq!(
            CompactionPolicy::default(),
            CompactionPolicy::Threshold(CompactionPolicy::DEFAULT_THRESHOLD)
        );
    }

    #[test]
    fn lifecycle_gather_then_compact_then_stale_then_rebuild() {
        let p = problem(1, 17, 60);
        let mut state = ScreeningState::new(p.n());
        let mut ws =
            WorkingSet::new(CompactionPolicy::Threshold(0.25), p.n());
        assert!(!ws.is_live());
        assert_matvec_parity(&mut ws, &p, &state, 10);

        // Round 1: drop half — 0.5 > 0.25 triggers the first rebuild.
        drop_every(&mut state, &mut ws, &p, 2);
        assert!(ws.is_live());
        assert!(ws.is_contiguous());
        assert_eq!(ws.rebuilds(), 1);
        assert_matvec_parity(&mut ws, &p, &state, 11);

        // Round 2: drop 1 atom of 30 — below threshold: stale gather.
        let keep: Vec<bool> =
            (0..state.active_count()).map(|k| k != 5).collect();
        state.retain(&keep);
        ws.on_retain(&p, &state, &keep);
        assert!(ws.is_live());
        assert!(!ws.is_contiguous());
        assert_eq!(ws.rebuilds(), 1);
        assert_matvec_parity(&mut ws, &p, &state, 12);

        // Round 3: drop half again — cumulative fraction clears 0.25.
        drop_every(&mut state, &mut ws, &p, 2);
        assert_eq!(ws.rebuilds(), 2);
        assert!(ws.is_contiguous());
        assert_matvec_parity(&mut ws, &p, &state, 13);
    }

    #[test]
    fn tail_only_removal_stays_contiguous() {
        let p = problem(2, 9, 40);
        let mut state = ScreeningState::new(p.n());
        let mut ws = WorkingSet::new(CompactionPolicy::Threshold(0.3), p.n());
        drop_every(&mut state, &mut ws, &p, 2); // rebuild
        assert!(ws.is_contiguous());
        // Drop the last few atoms only: pos stays a prefix identity, so
        // the blocked kernels keep applying without a rebuild.
        let k = state.active_count();
        let keep: Vec<bool> = (0..k).map(|i| i < k - 3).collect();
        state.retain(&keep);
        ws.on_retain(&p, &state, &keep);
        assert_eq!(ws.rebuilds(), 1);
        assert!(ws.is_contiguous());
        assert_matvec_parity(&mut ws, &p, &state, 14);
    }

    #[test]
    fn threshold_extremes() {
        let p = problem(3, 11, 50);
        // 0.0: every removing round rebuilds.
        let mut state = ScreeningState::new(p.n());
        let mut ws = WorkingSet::new(CompactionPolicy::Threshold(0.0), p.n());
        drop_every(&mut state, &mut ws, &p, 5);
        assert_eq!(ws.rebuilds(), 1);
        drop_every(&mut state, &mut ws, &p, 5);
        assert_eq!(ws.rebuilds(), 2);
        assert!(ws.is_contiguous());
        assert_matvec_parity(&mut ws, &p, &state, 15);
        // 1.0: never rebuilds.
        let mut state = ScreeningState::new(p.n());
        let mut ws = WorkingSet::new(CompactionPolicy::Threshold(1.0), p.n());
        drop_every(&mut state, &mut ws, &p, 2);
        drop_every(&mut state, &mut ws, &p, 2);
        assert!(!ws.is_live());
        assert_eq!(ws.rebuilds(), 0);
        assert_matvec_parity(&mut ws, &p, &state, 16);
        // Disabled: identical behavior to 1.0.
        let mut state = ScreeningState::new(p.n());
        let mut ws = WorkingSet::new(CompactionPolicy::Disabled, p.n());
        drop_every(&mut state, &mut ws, &p, 2);
        assert!(!ws.is_live());
        assert_matvec_parity(&mut ws, &p, &state, 17);
    }

    #[test]
    fn scaled_dual_scratch_matches_and_reuses() {
        let p = problem(4, 8, 20);
        let mut ws = WorkingSet::new(CompactionPolicy::default(), p.n());
        let mut g = Gen::for_case(4, 2);
        let r: Vec<f64> = (0..p.m()).map(|_| g.f64_in(-2.0, 2.0)).collect();
        let s = 0.73_f64;
        let mut flops = FlopCounter::new();
        let u1 = ws.scaled_dual(&r, s, &mut flops).to_vec();
        for (ui, ri) in u1.iter().zip(&r) {
            assert_eq!(ui.to_bits(), (s * ri).to_bits());
        }
        assert_eq!(flops.total(), p.m() as u64);
        let cap = ws.u.capacity();
        let _ = ws.scaled_dual(&r, 0.5, &mut flops);
        assert_eq!(ws.u.capacity(), cap, "scaled-dual buffer reallocated");
    }

    /// The `SparseStore` variant through the whole lifecycle (gather →
    /// compact → stale → rebuild), checked bitwise against the dense
    /// twin of the same matrix at every stage.
    #[test]
    fn sparse_store_lifecycle_matches_dense_twin_bitwise() {
        let mut g = Gen::for_case(21, 0);
        let (m, n) = (19usize, 70usize);
        let a = g.sparse_matrix(m, n, 0.35);
        let y: Vec<f64> = (0..m).map(|_| g.f64_in(-1.0, 1.0)).collect();
        let mut aty = vec![0.0; n];
        linalg::gemv_t(&a, &y, &mut aty);
        let lam = 0.5 * linalg::norm_inf(&aty).max(1e-9);
        let pd = LassoProblem::new(a.clone(), y.clone(), lam);
        let pc = LassoProblem::from_store(
            DictStore::Csc(CscMat::from_dense(&a)),
            y,
            lam,
        );
        assert_eq!(pd.col_nnz(), pc.col_nnz());

        let mut state = ScreeningState::new(n);
        let mut ws = WorkingSet::new(CompactionPolicy::Threshold(0.25), n);

        fn parity(
            ws: &mut WorkingSet,
            pd: &LassoProblem,
            pc: &LassoProblem,
            state: &ScreeningState,
            seed: u64,
        ) {
            let mut g = Gen::for_case(seed, 1);
            let m = pd.m();
            let k = state.active_count();
            let x: Vec<f64> = (0..k)
                .map(|i| if i % 3 == 0 { 0.0 } else { g.f64_in(-1.0, 1.0) })
                .collect();
            let r: Vec<f64> =
                (0..m).map(|_| g.f64_in(-1.0, 1.0)).collect();
            let ctx = ParContext::new_pool(4, 1);

            let mut want_ax = vec![0.0; m];
            linalg::gemv_cols(pd.a(), state.active(), &x, &mut want_ax);
            let mut got_ax = vec![f64::NAN; m];
            ws.gemv(pc, state.active(), &x, &mut got_ax, &ctx);
            for (w, got) in want_ax.iter().zip(&got_ax) {
                assert_eq!(w.to_bits(), got.to_bits(), "sparse Ax drift");
            }

            let mut want_atr = vec![0.0; k];
            linalg::gemv_t_cols(pd.a(), state.active(), &r, &mut want_atr);
            let mut got_atr = vec![f64::NAN; k];
            ws.gemv_t(pc, state.active(), &r, &mut got_atr, &ctx);
            for (w, got) in want_atr.iter().zip(&got_atr) {
                assert_eq!(w.to_bits(), got.to_bits(), "sparse Atr drift");
            }

            for (kp, &j) in state.active().iter().enumerate() {
                let view = ws.col_view(pc, state.active(), kp);
                assert!(matches!(view, ColView::Sparse { .. }));
                assert_eq!(
                    view.dot(&r).to_bits(),
                    linalg::dot(pd.a().col(j), &r).to_bits(),
                    "col_view dot drift"
                );
                assert_eq!(ws.col_nnz(pc, state.active(), kp),
                           pd.col_nnz()[j]);
                assert_eq!(
                    ws.col_norm(pc, state.active(), kp).to_bits(),
                    pd.col_norms()[j].to_bits()
                );
            }
            assert_eq!(
                ws.active_nnz(pc, state.active()),
                state
                    .active()
                    .iter()
                    .map(|&j| pd.col_nnz()[j] as u64)
                    .sum::<u64>()
            );
        }

        parity(&mut ws, &pd, &pc, &state, 30);
        // Round 1: drop half — triggers the first sparse rebuild.
        let keep: Vec<bool> =
            (0..state.active_count()).map(|k| k % 2 != 0).collect();
        state.retain(&keep);
        ws.on_retain(&pc, &state, &keep);
        assert!(ws.is_live());
        assert!(ws.is_contiguous());
        parity(&mut ws, &pd, &pc, &state, 31);
        // Round 2: drop one atom — stale sparse gather.
        let keep: Vec<bool> =
            (0..state.active_count()).map(|k| k != 3).collect();
        state.retain(&keep);
        ws.on_retain(&pc, &state, &keep);
        assert!(!ws.is_contiguous());
        parity(&mut ws, &pd, &pc, &state, 32);
        // Round 3: drop half again — sparse re-compaction.
        let keep: Vec<bool> =
            (0..state.active_count()).map(|k| k % 2 != 0).collect();
        state.retain(&keep);
        ws.on_retain(&pc, &state, &keep);
        assert!(ws.is_contiguous());
        assert_eq!(ws.rebuilds(), 2);
        parity(&mut ws, &pd, &pc, &state, 33);
    }

    #[test]
    fn reset_recycles_buffers() {
        let p = problem(5, 10, 30);
        let mut state = ScreeningState::new(p.n());
        let mut ws = WorkingSet::new(CompactionPolicy::Threshold(0.1), p.n());
        drop_every(&mut state, &mut ws, &p, 2);
        assert!(ws.is_live());
        let rebuilds = ws.rebuilds();
        ws.reset(p.n());
        assert!(!ws.is_live());
        assert!(!ws.is_contiguous());
        assert_eq!(ws.rebuilds(), rebuilds, "rebuild count is lifetime-wide");
        let state2 = ScreeningState::new(p.n());
        assert_matvec_parity(&mut ws, &p, &state2, 18);
    }
}
