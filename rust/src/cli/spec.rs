//! Declarative command/flag specifications.

/// The type a flag accepts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlagKind {
    /// Boolean presence flag (`--verbose`).
    Switch,
    /// `--key value` (or `--key=value`) parsed as string.
    Str,
    /// `--key value` parsed as f64.
    Num,
    /// `--key value` parsed as usize.
    Int,
}

/// One flag in a command spec.
#[derive(Clone, Copy, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub kind: FlagKind,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl Flag {
    pub const fn switch(name: &'static str, help: &'static str) -> Self {
        Flag { name, kind: FlagKind::Switch, default: None, help }
    }
    pub const fn str(
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        Flag { name, kind: FlagKind::Str, default, help }
    }
    pub const fn num(
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        Flag { name, kind: FlagKind::Num, default, help }
    }
    pub const fn int(
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        Flag { name, kind: FlagKind::Int, default, help }
    }
}

/// A subcommand: name, summary and accepted flags.
#[derive(Clone, Debug)]
pub struct Command {
    pub name: &'static str,
    pub summary: &'static str,
    pub flags: &'static [Flag],
}

impl Command {
    /// Render `--help` text for this command.
    pub fn help(&self, program: &str) -> String {
        let mut out = format!(
            "{program} {}\n  {}\n\nFlags:\n",
            self.name, self.summary
        );
        for f in self.flags {
            let kind = match f.kind {
                FlagKind::Switch => String::new(),
                FlagKind::Str => " <str>".to_string(),
                FlagKind::Num => " <num>".to_string(),
                FlagKind::Int => " <int>".to_string(),
            };
            let dflt = f
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!(
                "  --{}{kind}\n      {}{dflt}\n",
                f.name, f.help
            ));
        }
        out
    }
}

/// Render top-level help over a command list.
pub fn top_help(program: &str, about: &str, commands: &[Command]) -> String {
    let mut out = format!("{program} — {about}\n\nCommands:\n");
    let width = commands.iter().map(|c| c.name.len()).max().unwrap_or(0);
    for c in commands {
        out.push_str(&format!("  {:width$}  {}\n", c.name, c.summary));
    }
    out.push_str(&format!(
        "\nRun `{program} <command> --help` for command flags.\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLAGS: &[Flag] = &[
        Flag::int("seed", Some("0"), "RNG seed"),
        Flag::switch("verbose", "chatty output"),
    ];

    #[test]
    fn help_contains_flags_and_defaults() {
        let cmd = Command { name: "solve", summary: "solve one", flags: FLAGS };
        let h = cmd.help("prog");
        assert!(h.contains("--seed <int>"));
        assert!(h.contains("[default: 0]"));
        assert!(h.contains("--verbose"));
    }

    #[test]
    fn top_help_lists_commands() {
        let cmds = [
            Command { name: "a", summary: "first", flags: &[] },
            Command { name: "bb", summary: "second", flags: &[] },
        ];
        let h = top_help("prog", "about", &cmds);
        assert!(h.contains("first"));
        assert!(h.contains("bb"));
    }
}
