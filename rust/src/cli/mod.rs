//! CLI substrate (no clap): declarative flag specs, subcommands, `--help`
//! generation and typed accessors.

pub mod parser;
pub mod spec;

pub use parser::{Args, CliError};
pub use spec::{Command, Flag, FlagKind};
