//! Flag parsing against a [`Command`] spec.

use std::collections::BTreeMap;

use super::spec::{Command, FlagKind};

/// Parse error (unknown flag, missing value, bad type...).
#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Parsed arguments: typed access by flag name.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// Non-flag positional arguments, in order.
    pub positional: Vec<String>,
    pub help_requested: bool,
}

impl Args {
    /// Parse `argv` (without program/command names) against `spec`.
    pub fn parse(spec: &Command, argv: &[String]) -> Result<Args, CliError> {
        let mut values = BTreeMap::new();
        let mut switches = BTreeMap::new();
        let mut positional = Vec::new();
        let mut help = false;

        // Seed defaults.
        for f in spec.flags {
            if let Some(d) = f.default {
                values.insert(f.name.to_string(), d.to_string());
            }
            if f.kind == FlagKind::Switch {
                switches.insert(f.name.to_string(), false);
            }
        }

        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                help = true;
                i += 1;
                continue;
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let flag = spec
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        CliError(format!(
                            "unknown flag --{name} for '{}'",
                            spec.name
                        ))
                    })?;
                match flag.kind {
                    FlagKind::Switch => {
                        if inline.is_some() {
                            return Err(CliError(format!(
                                "--{name} takes no value"
                            )));
                        }
                        switches.insert(name.to_string(), true);
                    }
                    _ => {
                        let val = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| {
                                        CliError(format!(
                                            "--{name} requires a value"
                                        ))
                                    })?
                            }
                        };
                        // Type-check eagerly for better messages.
                        match flag.kind {
                            FlagKind::Num => {
                                val.parse::<f64>().map_err(|_| {
                                    CliError(format!(
                                        "--{name}: '{val}' is not a number"
                                    ))
                                })?;
                            }
                            FlagKind::Int => {
                                val.parse::<usize>().map_err(|_| {
                                    CliError(format!(
                                        "--{name}: '{val}' is not an integer"
                                    ))
                                })?;
                            }
                            _ => {}
                        }
                        values.insert(name.to_string(), val);
                    }
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Args { values, switches, positional, help_requested: help })
    }

    pub fn str(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.str(name).unwrap_or(default)
    }

    pub fn num(&self, name: &str) -> Option<f64> {
        self.values.get(name).and_then(|v| v.parse().ok())
    }

    pub fn num_or(&self, name: &str, default: f64) -> f64 {
        self.num(name).unwrap_or(default)
    }

    pub fn int(&self, name: &str) -> Option<usize> {
        self.values.get(name).and_then(|v| v.parse().ok())
    }

    pub fn int_or(&self, name: &str, default: usize) -> usize {
        self.int(name).unwrap_or(default)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::spec::Flag;

    const FLAGS: &[Flag] = &[
        Flag::int("seed", Some("7"), "seed"),
        Flag::num("lam", Some("0.5"), "lambda ratio"),
        Flag::str("dict", Some("gaussian"), "dictionary"),
        Flag::switch("verbose", "chatty"),
    ];
    const CMD: Command =
        Command { name: "solve", summary: "s", flags: FLAGS };

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&CMD, &sv(&[])).unwrap();
        assert_eq!(a.int_or("seed", 0), 7);
        assert_eq!(a.num_or("lam", 0.0), 0.5);
        assert_eq!(a.str_or("dict", ""), "gaussian");
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let a = Args::parse(
            &CMD,
            &sv(&["--seed", "9", "--lam=0.8", "--verbose", "pos1"]),
        )
        .unwrap();
        assert_eq!(a.int_or("seed", 0), 9);
        assert_eq!(a.num_or("lam", 0.0), 0.8);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&CMD, &sv(&["--nope"])).is_err());
        assert!(Args::parse(&CMD, &sv(&["--seed"])).is_err());
        assert!(Args::parse(&CMD, &sv(&["--seed", "abc"])).is_err());
        assert!(Args::parse(&CMD, &sv(&["--lam", "xyz"])).is_err());
        assert!(Args::parse(&CMD, &sv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn help_flag() {
        let a = Args::parse(&CMD, &sv(&["--help"])).unwrap();
        assert!(a.help_requested);
    }
}
