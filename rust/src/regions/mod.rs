//! Safe regions: the paper's Hölder dome (§IV) plus the GAP regions of
//! Fercoq et al. (§III-C) and two classical sphere baselines.
//!
//! Every region is built from a primal-dual feasible couple `(x, u)`
//! where `u` is the dual-scaled residual at `x` (El Ghaoui §3.3):
//!
//! | kind          | geometry                                   | eq.   |
//! |---------------|--------------------------------------------|-------|
//! | `GapSphere`   | `B(u, √(2·gap))`                           | 16-17 |
//! | `GapDome`     | `B((y+u)/2, ‖y−u‖/2) ∩ H(y−c, ⟨g,c⟩+gap−R²)` | 18-21 |
//! | `HolderDome`  | same ball ∩ `H(Ax, λ‖x‖₁)`                 | Thm 1 |
//! | `StaticSphere`| `B(y, (1−λ/λ_max)‖y‖)` (El Ghaoui, static) | [5]   |
//! | `DynamicSphere`| `B(y, ‖y−u‖)` (Bonnefoy et al.)           | [7]   |
//! | `Sequential`  | the Hölder dome at a *warm-start* couple   | Thm 1 |
//!
//! ## Sequential screening (`RegionKind::Sequential`)
//!
//! The GAP Safe *sequential* rules (Fercoq et al.) and the EDPP path
//! rules (Wang et al.) exploit the fact that a previous solve's
//! primal-dual couple yields a tight safe region for the *next* nearby
//! solve — same observation at a neighboring λ, or a near-duplicate
//! observation.  `Sequential` is that idea expressed inside this
//! repo's geometry: it is the Hölder dome (Theorem 1) **instantiated
//! at a warm-start couple** `(x₀, u₀)` where `x₀` came from somewhere
//! else (a session cache, a λ-path predecessor) and `u₀ = s·r₀` is the
//! *freshly dual-scaled* residual `r₀ = y − A x₀` at the **current**
//! λ.  Theorem 1 holds for any primal point and any dual-feasible
//! point, and dual scaling makes `u₀` feasible by construction — so
//! the region is safe *no matter where `x₀` came from*: a stale or
//! mismatched seed can only cost screening power, never correctness.
//! The solvers run it as an iteration-0 seed round
//! ([`crate::solver::SolverConfig::seed_region`]) so a cache hit
//! starts its first iteration on the already-reduced dictionary
//! (see `coordinator::cache`).
//!
//! ## Correlation reuse
//!
//! The screening engine never forms `Aᵀc`/`Aᵀg` with fresh matvecs.
//! With `Aᵀy` cached and `Aᵀr` available from dual scaling (`u = s·r` ⇒
//! `Aᵀu = s·Aᵀr`), each region's per-atom statistics are affine
//! combinations recorded here as [`StatCombo`] coefficients:
//!
//! ```text
//!   ⟨a_i, c⟩ = combo_c.0 · (Aᵀy)_i + combo_c.1 · (Aᵀr)_i
//!   ⟨a_i, g⟩ = combo_g.0 · (Aᵀy)_i + combo_g.1 · (Aᵀr)_i
//! ```
//!
//! (Hölder: `g = Ax = y − r` ⇒ `Aᵀg = Aᵀy − Aᵀr`, coefficients (1, −1).)
//! This realizes the paper's "same computational burden" claim: all five
//! regions cost O(n_active + m) per test on top of the solver's own
//! matvecs.
//!
//! ## Sharded evaluation
//!
//! Because each atom's test is a pure O(1) function of its cached
//! statistics (the table above), the screening engine evaluates the
//! active set **shard-parallel**: contiguous chunks of at least
//! `shard_min` atoms (default
//! [`crate::par::DEFAULT_SHARD_MIN`]) are fanned out on the
//! [`crate::par::ParContext`]'s pool, each writing its own disjoint
//! slice of the keep mask.  Region construction itself (O(m) vector
//! work, once per round) stays on the calling thread.  Determinism:
//! every per-atom bound is computed by exactly the sequential
//! instruction sequence regardless of shard count, so the keep mask —
//! and hence the whole solve — is bitwise independent of threading.
//! Below `2·shard_min` active atoms the engine falls back to the
//! sequential loop, so endgame rounds (tiny active sets) pay no
//! dispatch overhead.
//!
//! ## The working-set lifecycle (screen → retain → compact → blocked kernels)
//!
//! Region tests don't just shrink the active *index list* — they feed
//! the [`crate::workset::WorkingSet`], which physically re-materializes
//! the surviving atoms once enough of them are gone:
//!
//! 1. **screen** — the engine evaluates this module's per-atom bounds
//!    and produces a keep mask;
//! 2. **retain** — `ScreeningState::retain` drops the screened indices
//!    and the solver compacts its coefficient vectors with the same
//!    mask;
//! 3. **compact** — when the removed fraction since the last rebuild
//!    clears the [`crate::workset::CompactionPolicy`] threshold, the
//!    surviving columns (plus per-atom `‖a_i‖` / `(Aᵀy)_i` / nnz
//!    caches used by the statistics recipes above) are copied into
//!    contiguous storage **in the dictionary's format** — a dense
//!    [`crate::linalg::Mat`], or, for CSC-backed problems
//!    ([`crate::sparse::DictStore`]), a `SparseStore`: the surviving
//!    columns' nonzero `(row_idx, val)` runs gathered into a compact
//!    [`crate::sparse::CscMat`];
//! 4. **blocked kernels** — subsequent iterations stream that storage
//!    with the indirection-free matvecs
//!    ([`crate::linalg::gemv_compact_sharded`] /
//!    [`crate::linalg::gemv_t_blocked_sharded`] dense,
//!    [`crate::linalg::spmv_compact_sharded`] /
//!    [`crate::linalg::spmv_t_compact_sharded`] sparse), and the
//!    screening test itself reads the compact stat caches contiguously
//!    (`ScreeningEngine::compute_keep_ws`) — the per-atom statistics
//!    are scalars, so the test body never touches the matrix and is
//!    storage-format-agnostic by construction.
//!
//! The per-atom bound arithmetic is identical in every mode, so the
//! keep mask — and the whole solve — is bitwise independent of the
//! compaction policy, the dictionary storage format, and threading.

use crate::flops::cost::{self, ScreenSetupKind};
use crate::geometry::{Ball, Dome, HalfSpace};
use crate::linalg;
use crate::problem::{LassoProblem, PrimalDualEval};

/// Which safe region to use for screening.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RegionKind {
    GapSphere,
    GapDome,
    HolderDome,
    StaticSphere,
    DynamicSphere,
    /// The Hölder dome built at a warm-start couple — the sequential
    /// screening region seeded by the session cache (see the module
    /// docs).  Geometrically identical to [`RegionKind::HolderDome`];
    /// kept distinct so configs, metrics and reports can tell a
    /// sequential seed round from ordinary dynamic screening.
    Sequential,
}

impl RegionKind {
    pub const ALL: [RegionKind; 6] = [
        RegionKind::GapSphere,
        RegionKind::GapDome,
        RegionKind::HolderDome,
        RegionKind::StaticSphere,
        RegionKind::DynamicSphere,
        RegionKind::Sequential,
    ];

    /// The paper's Fig. 2 contenders.
    pub const PAPER: [RegionKind; 3] = [
        RegionKind::GapSphere,
        RegionKind::GapDome,
        RegionKind::HolderDome,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RegionKind::GapSphere => "gap_sphere",
            RegionKind::GapDome => "gap_dome",
            RegionKind::HolderDome => "holder_dome",
            RegionKind::StaticSphere => "static_sphere",
            RegionKind::DynamicSphere => "dynamic_sphere",
            RegionKind::Sequential => "sequential",
        }
    }

    pub fn parse(s: &str) -> Option<RegionKind> {
        match s.to_ascii_lowercase().replace('-', "_").as_str() {
            "gap_sphere" | "gapsphere" | "sphere" => Some(RegionKind::GapSphere),
            "gap_dome" | "gapdome" => Some(RegionKind::GapDome),
            "holder_dome" | "holder" | "hoelder" => Some(RegionKind::HolderDome),
            "static_sphere" | "static" | "safe" => Some(RegionKind::StaticSphere),
            "dynamic_sphere" | "dynamic" | "st1" => Some(RegionKind::DynamicSphere),
            "sequential" | "seq" => Some(RegionKind::Sequential),
            _ => None,
        }
    }
}

/// Affine combination `alpha·(Aᵀy)_i + beta·(Aᵀr)_i` used to synthesize
/// per-atom correlations without extra matvecs.
pub type StatCombo = (f64, f64);

/// Relative inflation applied to every joint-screening group bound
/// ([`SafeRegion::group_bound`]) before it is compared against λ.
///
/// In exact arithmetic the group bound dominates each member's
/// per-atom bound, so a group that screens implies every member
/// screens and the keep mask is identical with grouping on or off.
/// Floating point evaluates the two sides along different instruction
/// sequences, whose results can disagree by a few ulps (~1e-16
/// relative) — three orders of magnitude below this margin.  Inflating
/// the group bound by `1e-12·(1 + |bound|)` therefore makes the
/// real-arithmetic dominance hold *bitwise*: a group only screens when
/// every member's individually computed bound is strictly below λ too.
/// The cost is a vanishing loss of group-test power, never safety.
pub const GROUP_FP_MARGIN: f64 = 1e-12;

/// The geometric payload of a safe region.
#[derive(Clone, Debug)]
pub enum RegionGeom {
    Sphere(Ball),
    Dome(Dome),
}

/// A constructed safe region, with the statistic recipes for the fast
/// test path.
#[derive(Clone, Debug)]
pub struct SafeRegion {
    pub kind: RegionKind,
    pub geom: RegionGeom,
    /// ⟨a_i, c⟩ as a (Aᵀy, Aᵀr) combination.
    pub combo_c: StatCombo,
    /// ⟨a_i, g⟩ as a (Aᵀy, Aᵀr) combination (`None` for spheres).
    pub combo_g: Option<StatCombo>,
}

impl SafeRegion {
    /// Build a region from the primal point `x` and its evaluation
    /// (residual, scaled dual point, gap).
    pub fn build(
        kind: RegionKind,
        p: &LassoProblem,
        x: &[f64],
        ev: &PrimalDualEval,
    ) -> SafeRegion {
        Self::build_parts(kind, p, x, &ev.u, &ev.r, ev.gap, ev.scale)
    }

    /// [`build`](Self::build) from borrowed couple parts — the solver
    /// hot path, where `u` lives in the working set's reusable
    /// scaled-dual scratch and no `PrimalDualEval` is materialized.
    ///
    /// `u` must be the dual-scaled residual `s·r` and `gap`/`scale`
    /// the matching duality gap and scaling factor; `x` is the compact
    /// iterate (used only through `λ‖x‖₁` for the Hölder half-space).
    pub fn build_parts(
        kind: RegionKind,
        p: &LassoProblem,
        x: &[f64],
        u: &[f64],
        r: &[f64],
        gap: f64,
        scale: f64,
    ) -> SafeRegion {
        let y = p.y();
        let s = scale;
        match kind {
            RegionKind::GapSphere => {
                let radius = (2.0 * gap.max(0.0)).sqrt();
                SafeRegion {
                    kind,
                    geom: RegionGeom::Sphere(Ball::new(u.to_vec(), radius)),
                    combo_c: (0.0, s),
                    combo_g: None,
                }
            }
            RegionKind::GapDome => {
                let (ball, _) = midpoint_ball(y, u);
                let radius = ball.radius;
                // g = y − c = (y − u)/2; δ = ⟨g,c⟩ + gap − R².
                let g: Vec<f64> = y
                    .iter()
                    .zip(u)
                    .map(|(yi, ui)| 0.5 * (yi - ui))
                    .collect();
                let delta =
                    linalg::dot(&g, &ball.center) + gap - radius * radius;
                SafeRegion {
                    kind,
                    geom: RegionGeom::Dome(Dome::new(
                        ball,
                        HalfSpace::new(g, delta),
                    )),
                    combo_c: (0.5, 0.5 * s),
                    combo_g: Some((0.5, -0.5 * s)),
                }
            }
            RegionKind::HolderDome | RegionKind::Sequential => {
                // `Sequential` is the same Theorem-1 dome, built at a
                // warm-start couple: `x` is a seed iterate from a
                // previous solve and `u` its freshly dual-scaled
                // residual at the *current* λ.  Theorem 1 never asks
                // where `x` came from, so the construction is shared.
                let (ball, _) = midpoint_ball(y, u);
                // g = Ax = y − r (no matvec); δ = λ‖x‖₁.
                let g: Vec<f64> =
                    y.iter().zip(r).map(|(yi, ri)| yi - ri).collect();
                let delta = p.lam() * linalg::norm1(x);
                SafeRegion {
                    kind,
                    geom: RegionGeom::Dome(Dome::new(
                        ball,
                        HalfSpace::new(g, delta),
                    )),
                    combo_c: (0.5, 0.5 * s),
                    combo_g: Some((1.0, -1.0)),
                }
            }
            RegionKind::StaticSphere => {
                // u* is the projection of y on U; θ0 = (λ/λ_max)·y is
                // feasible, so ‖y − u*‖ ≤ ‖y − θ0‖ = (1 − λ/λ_max)‖y‖.
                let radius = (1.0 - p.lam() / p.lam_max()).max(0.0)
                    * linalg::norm2(y);
                SafeRegion {
                    kind,
                    geom: RegionGeom::Sphere(Ball::new(y.to_vec(), radius)),
                    combo_c: (1.0, 0.0),
                    combo_g: None,
                }
            }
            RegionKind::DynamicSphere => {
                // Projection property again, with the current u:
                // ‖y − u*‖ ≤ ‖y − u‖.
                let mut diff = vec![0.0; y.len()];
                linalg::sub(y, u, &mut diff);
                let radius = linalg::norm2(&diff);
                SafeRegion {
                    kind,
                    geom: RegionGeom::Sphere(Ball::new(y.to_vec(), radius)),
                    combo_c: (1.0, 0.0),
                    combo_g: None,
                }
            }
        }
    }

    /// `Rad(·)` of eq. (32).
    pub fn rad(&self) -> f64 {
        match &self.geom {
            RegionGeom::Sphere(b) => b.rad(),
            RegionGeom::Dome(d) => d.rad(),
        }
    }

    /// Membership test (region safety checks in tests).
    pub fn contains(&self, u: &[f64], tol: f64) -> bool {
        match &self.geom {
            RegionGeom::Sphere(b) => b.contains(u, tol),
            RegionGeom::Dome(d) => d.contains(u, tol),
        }
    }

    /// `max_{u∈R} |⟨a, u⟩|` from the explicit atom vector (slow path).
    pub fn max_abs_inner(&self, a: &[f64]) -> f64 {
        match &self.geom {
            RegionGeom::Sphere(b) => b.max_abs_inner(a),
            RegionGeom::Dome(d) => d.max_abs_inner(a),
        }
    }

    /// `max_{u∈R} |⟨a_i, u⟩|` from per-atom statistics (hot path).
    ///
    /// `aty_i`/`atr_i` are the cached/current correlations, `anrm` the
    /// atom norm; the recipes in `combo_c`/`combo_g` assemble
    /// `⟨a_i,c⟩`/`⟨a_i,g⟩`.
    #[inline]
    pub fn max_abs_inner_stat(&self, aty_i: f64, atr_i: f64, anrm: f64) -> f64 {
        let atc = self.combo_c.0 * aty_i + self.combo_c.1 * atr_i;
        match &self.geom {
            RegionGeom::Sphere(b) => b.max_abs_inner_stat(atc, anrm),
            RegionGeom::Dome(d) => {
                let (ga, gb) = self.combo_g.expect("dome without combo_g");
                let atg = ga * aty_i + gb * atr_i;
                d.max_abs_inner_stat(atc, atg, anrm)
            }
        }
    }

    /// Upper bound on `sup_{u∈R} ‖u‖` — the dual-norm factor of the
    /// joint screening test.  For spheres this is exact
    /// (`‖center‖ + radius`); for domes it is the closed-form maximum
    /// over **ball ∩ half-space** ([`Dome::sup_norm`]): when the ball's
    /// farthest-from-origin point violates the cut, the maximizer sits
    /// on the cap rim and the cut shrinks the bound — strictly tighter
    /// exactly where the Hölder dome is strictly smaller than the GAP
    /// sphere, so group tests certify more runs near convergence.
    /// Never exceeds the enclosing-ball value (asserted by the
    /// `dome_sup_never_exceeds_ball_sup` property), and conservatively
    /// fp-inflated on the rim branch
    /// ([`crate::geometry::dome::SUP_NORM_FP_MARGIN`]) so floating
    /// point cannot round it below the true supremum.  O(m), once per
    /// screening round.
    pub fn sup_dual_norm(&self) -> f64 {
        match &self.geom {
            RegionGeom::Sphere(b) => linalg::norm2(&b.center) + b.radius,
            RegionGeom::Dome(d) => d.sup_norm(),
        }
    }

    /// The joint screening test bound (Herzet & Drémeau): for any atom
    /// `a` with `‖a − a_pivot‖ ≤ ball_dist`,
    ///
    /// ```text
    ///   sup_{u∈R} |⟨a, u⟩|  ≤  sup_{u∈R} |⟨a_pivot, u⟩|
    ///                          + ball_dist · sup_{u∈R} ‖u‖
    /// ```
    ///
    /// `pivot_bound` is the pivot's own [`max_abs_inner_stat`]
    /// (exactly the flat pass's per-atom bound), `sup_u` the cached
    /// [`sup_dual_norm`].  The result is inflated by
    /// [`GROUP_FP_MARGIN`] so that in floating point too, a group
    /// bound below λ certifies every member's per-atom bound is below
    /// λ — the bitwise-parity contract of grouped screening.
    ///
    /// [`max_abs_inner_stat`]: Self::max_abs_inner_stat
    /// [`sup_dual_norm`]: Self::sup_dual_norm
    #[inline]
    pub fn group_bound(
        &self,
        pivot_bound: f64,
        ball_dist: f64,
        sup_u: f64,
    ) -> f64 {
        let core = pivot_bound + ball_dist * sup_u;
        core + GROUP_FP_MARGIN * (1.0 + core.abs())
    }

    /// Flop cost of *building* this region's statistics for `n_active`
    /// atoms in dimension `m` (see [`crate::flops`]).
    pub fn setup_flops(&self, n_active: usize, m: usize) -> u64 {
        let kind = match self.kind {
            RegionKind::GapSphere
            | RegionKind::StaticSphere
            | RegionKind::DynamicSphere => ScreenSetupKind::GapSphere,
            RegionKind::GapDome => ScreenSetupKind::GapDome,
            RegionKind::HolderDome | RegionKind::Sequential => {
                ScreenSetupKind::Holder
            }
        };
        cost::screen_setup(kind, n_active, m)
    }

    /// Flop cost of *running* the test over `n_active` atoms.
    pub fn test_flops(&self, n_active: usize) -> u64 {
        match &self.geom {
            RegionGeom::Sphere(_) => cost::sphere_test(n_active),
            RegionGeom::Dome(_) => cost::dome_test(n_active),
        }
    }
}

/// Ball `B((y+u)/2, ‖y−u‖/2)` shared by both dome regions.
fn midpoint_ball(y: &[f64], u: &[f64]) -> (Ball, f64) {
    let center: Vec<f64> = y
        .iter()
        .zip(u)
        .map(|(yi, ui)| 0.5 * (yi + ui))
        .collect();
    let mut diff = vec![0.0; y.len()];
    linalg::sub(y, u, &mut diff);
    let radius = 0.5 * linalg::norm2(&diff);
    (Ball::new(center, radius), radius)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{Gen, Runner};

    /// Generate a problem plus a primal iterate with its evaluation.
    fn setup(g: &mut Gen) -> (LassoProblem, Vec<f64>, PrimalDualEval) {
        let m = g.usize_in(5, 25);
        let n = g.usize_in(8, 60);
        let a = g.dictionary(m, n);
        let y = g.observation(m);
        let mut aty = vec![0.0; n];
        linalg::gemv_t(&a, &y, &mut aty);
        let lam_max = linalg::norm_inf(&aty);
        let lam = g.f64_in(0.2, 0.9) * lam_max.max(1e-6);
        let p = LassoProblem::new(a, y, lam);
        // A plausible iterate: a few soft-thresholded gradient steps.
        let mut x = vec![0.0; n];
        let step = p.default_step();
        for _ in 0..g.usize_in(0, 8) {
            let ev = p.eval(&x);
            for i in 0..n {
                x[i] = linalg::soft_threshold_scalar(
                    x[i] + step * ev.atr[i],
                    step * lam,
                );
            }
        }
        let ev = p.eval(&x);
        (p, x, ev)
    }

    /// High-accuracy dual optimum (many FISTA steps).
    fn dual_optimum(p: &LassoProblem) -> Vec<f64> {
        let mut x = vec![0.0; p.n()];
        let mut z = x.clone();
        let mut t = 1.0f64;
        let step = p.default_step();
        for _ in 0..6000 {
            let ev = p.eval(&z);
            let mut x_new = vec![0.0; p.n()];
            for i in 0..p.n() {
                x_new[i] = linalg::soft_threshold_scalar(
                    z[i] + step * ev.atr[i],
                    step * p.lam(),
                );
            }
            let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let beta = (t - 1.0) / t_new;
            for i in 0..p.n() {
                z[i] = x_new[i] + beta * (x_new[i] - x[i]);
            }
            x = x_new;
            t = t_new;
        }
        p.eval(&x).u
    }

    #[test]
    fn all_regions_contain_dual_optimum() {
        Runner::new(101).cases(8).run("safety of all regions", |g| {
            let (p, x, ev) = setup(g);
            let u_star = dual_optimum(&p);
            for kind in RegionKind::ALL {
                let region = SafeRegion::build(kind, &p, &x, &ev);
                if !region.contains(&u_star, 1e-6) {
                    return Err(format!(
                        "{} does not contain u*",
                        kind.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn holder_dome_inside_gap_dome_inside_gap_sphere() {
        // Theorem 2 + eq. (22), checked pointwise on the per-atom maxima
        // (subset ⇒ max over subset ≤ max over superset).
        Runner::new(103).cases(20).run("dominance chain", |g| {
            let (p, x, ev) = setup(g);
            let sph = SafeRegion::build(RegionKind::GapSphere, &p, &x, &ev);
            let dom = SafeRegion::build(RegionKind::GapDome, &p, &x, &ev);
            let hld = SafeRegion::build(RegionKind::HolderDome, &p, &x, &ev);
            for i in 0..p.n() {
                let aty_i = p.aty()[i];
                let atr_i = ev.atr[i];
                let anrm = p.col_norms()[i];
                let ms = sph.max_abs_inner_stat(aty_i, atr_i, anrm);
                let mg = dom.max_abs_inner_stat(aty_i, atr_i, anrm);
                let mh = hld.max_abs_inner_stat(aty_i, atr_i, anrm);
                if mg > ms + 1e-9 {
                    return Err(format!("atom {i}: gap dome {mg} > sphere {ms}"));
                }
                if mh > mg + 1e-9 {
                    return Err(format!("atom {i}: holder {mh} > gap dome {mg}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rad_ordering_theorem2() {
        // Rad(Holder) <= Rad(GapDome) <= Rad(GapSphere).
        Runner::new(107).cases(30).run("radius ordering", |g| {
            let (p, x, ev) = setup(g);
            let r_s =
                SafeRegion::build(RegionKind::GapSphere, &p, &x, &ev).rad();
            let r_g =
                SafeRegion::build(RegionKind::GapDome, &p, &x, &ev).rad();
            let r_h =
                SafeRegion::build(RegionKind::HolderDome, &p, &x, &ev).rad();
            if r_g > r_s + 1e-9 {
                return Err(format!("rad gap dome {r_g} > gap sphere {r_s}"));
            }
            if r_h > r_g + 1e-9 {
                return Err(format!("rad holder {r_h} > gap dome {r_g}"));
            }
            Ok(())
        });
    }

    #[test]
    fn stat_path_matches_explicit_path() {
        Runner::new(109).cases(20).run("stat == explicit", |g| {
            let (p, x, ev) = setup(g);
            for kind in RegionKind::ALL {
                let region = SafeRegion::build(kind, &p, &x, &ev);
                for i in 0..p.n().min(10) {
                    let explicit = region.max_abs_inner(p.a().col(i));
                    let stat = region.max_abs_inner_stat(
                        p.aty()[i],
                        ev.atr[i],
                        p.col_norms()[i],
                    );
                    if (explicit - stat).abs() > 1e-8 {
                        return Err(format!(
                            "{} atom {i}: explicit {explicit} vs stat {stat}",
                            kind.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gap_sphere_collapses_at_optimum() {
        let mut g = Gen::for_case(7, 0);
        let (p, _, _) = setup(&mut g);
        // near-optimal x
        let mut x = vec![0.0; p.n()];
        let step = p.default_step();
        let mut z = x.clone();
        let mut t = 1.0f64;
        for _ in 0..4000 {
            let ev = p.eval(&z);
            let mut x_new = vec![0.0; p.n()];
            for i in 0..p.n() {
                x_new[i] = linalg::soft_threshold_scalar(
                    z[i] + step * ev.atr[i],
                    step * p.lam(),
                );
            }
            let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let beta = (t - 1.0) / t_new;
            for i in 0..p.n() {
                z[i] = x_new[i] + beta * (x_new[i] - x[i]);
            }
            x = x_new;
            t = t_new;
        }
        let ev = p.eval(&x);
        assert!(ev.gap < 1e-10, "did not converge: gap {}", ev.gap);
        let sphere = SafeRegion::build(RegionKind::GapSphere, &p, &x, &ev);
        assert!(sphere.rad() < 2e-5, "rad {}", sphere.rad());
    }

    #[test]
    fn strict_inclusion_under_theorem2_hypotheses() {
        // If P(x) < P(0) and (x,u) not optimal, Rad(holder) < Rad(gap).
        Runner::new(113).cases(25).run("strict inclusion", |g| {
            let (p, x, ev) = setup(g);
            let p0 = 0.5 * linalg::norm2_sq(p.y());
            if ev.p >= p0 || ev.gap < 1e-10 {
                return Ok(()); // hypotheses not met
            }
            let r_g =
                SafeRegion::build(RegionKind::GapDome, &p, &x, &ev).rad();
            let r_h =
                SafeRegion::build(RegionKind::HolderDome, &p, &x, &ev).rad();
            if r_h >= r_g - 1e-12 && r_g > 1e-9 {
                // Radii can coincide even under strict set inclusion
                // (both caps wider than a hemisphere both give R), so
                // only flag when the HALF-SPACES are provably ordered
                // strictly and the radii still disagree the wrong way.
                if r_h > r_g + 1e-12 {
                    return Err(format!("holder rad {r_h} > gap rad {r_g}"));
                }
            }
            Ok(())
        });
    }

    /// The joint-screening bound chain: for every region kind and any
    /// "cluster" of atoms, the group bound computed from one pivot and
    /// the true pairwise distances dominates every member's per-atom
    /// bound — with strict slack at least the fp margin, which is what
    /// the grouped engine's bitwise-parity contract rests on.
    #[test]
    fn group_bound_dominates_member_bounds() {
        Runner::new(131).cases(15).run("group bound dominance", |g| {
            let (p, x, ev) = setup(g);
            let n = p.n();
            for kind in RegionKind::ALL {
                let region = SafeRegion::build(kind, &p, &x, &ev);
                let sup_u = region.sup_dual_norm();
                // The dome-aware sup must never exceed the enclosing
                // ball's — the conservative envelope the flat grouped
                // pass shipped with.
                if let RegionGeom::Dome(d) = &region.geom {
                    let ball_sup =
                        linalg::norm2(&d.ball.center) + d.ball.radius;
                    if sup_u > ball_sup {
                        return Err(format!(
                            "{}: dome sup {sup_u} > ball sup {ball_sup}",
                            kind.name()
                        ));
                    }
                }
                // treat a random contiguous window as one cluster,
                // pivoting on its first atom
                let start = g.usize_in(0, n - 1);
                let end = (start + g.usize_in(1, 8)).min(n);
                let pivot = start;
                let pb = region.max_abs_inner_stat(
                    p.aty()[pivot],
                    ev.atr[pivot],
                    p.col_norms()[pivot],
                );
                for i in start..end {
                    let diff: Vec<f64> = p
                        .a()
                        .col(i)
                        .iter()
                        .zip(p.a().col(pivot))
                        .map(|(a, b)| a - b)
                        .collect();
                    let dist = linalg::norm2(&diff);
                    let gb = region.group_bound(pb, dist, sup_u);
                    let mb = region.max_abs_inner_stat(
                        p.aty()[i],
                        ev.atr[i],
                        p.col_norms()[i],
                    );
                    if mb >= gb {
                        return Err(format!(
                            "{} atom {i}: member bound {mb} >= group \
                             bound {gb} (pivot {pivot})",
                            kind.name()
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The dome-aware `sup_dual_norm` path at every cut regime the
    /// geometry admits — active, inactive, tangent from either side,
    /// and a radius-0 ball — checked against the explicit (slow-path)
    /// member bounds: the group bound must dominate each member's
    /// per-atom bound, and the dome sup must never exceed the
    /// enclosing-ball sup.
    #[test]
    fn group_bound_dominates_on_synthetic_domes() {
        Runner::new(137).cases(25).run("synthetic dome dominance", |g| {
            let m = g.usize_in(3, 12);
            let n = 12;
            let a = g.dictionary(m, n);
            let center = g.vec_normal(m);
            let normal = g.vec_normal(m);
            let gn = linalg::norm2(&normal);
            let cases: [(f64, f64); 6] = [
                (g.f64_in(0.1, 1.5), g.f64_in(-0.95, 0.0)), // cut active
                (g.f64_in(0.1, 1.5), g.f64_in(0.0, 0.95)),  // maybe active
                (g.f64_in(0.1, 1.5), 2.0), // inactive (misses the ball)
                (g.f64_in(0.1, 1.5), 1.0), // tangent, whole ball inside
                (g.f64_in(0.1, 1.5), -1.0), // tangent, single-point dome
                (0.0, 0.5),                 // radius-0 ball
            ];
            for (case, (radius, dpos)) in cases.into_iter().enumerate() {
                let delta = linalg::dot(&normal, &center)
                    + dpos * radius * gn;
                let dome = Dome::new(
                    Ball::new(center.clone(), radius),
                    HalfSpace::new(normal.clone(), delta),
                );
                let region = SafeRegion {
                    kind: RegionKind::HolderDome,
                    geom: RegionGeom::Dome(dome),
                    combo_c: (0.0, 0.0),
                    combo_g: None,
                };
                let sup_u = region.sup_dual_norm();
                let ball_sup = linalg::norm2(&center) + radius;
                if sup_u > ball_sup {
                    return Err(format!(
                        "case {case}: dome sup {sup_u} > ball \
                         sup {ball_sup}"
                    ));
                }
                if dpos >= 1.0 && sup_u.to_bits() != ball_sup.to_bits() {
                    return Err(format!(
                        "case {case}: inactive cut must return the \
                         ball sup bitwise ({sup_u} vs {ball_sup})"
                    ));
                }
                let pivot = 0;
                let pb = region.max_abs_inner(a.col(pivot));
                for i in 0..n {
                    let diff: Vec<f64> = a
                        .col(i)
                        .iter()
                        .zip(a.col(pivot))
                        .map(|(x, y)| x - y)
                        .collect();
                    let dist = linalg::norm2(&diff);
                    let gb = region.group_bound(pb, dist, sup_u);
                    let mb = region.max_abs_inner(a.col(i));
                    if mb >= gb {
                        return Err(format!(
                            "case {case} atom {i}: member bound {mb} \
                             >= group bound {gb}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parse_and_name_round_trip() {
        for kind in RegionKind::ALL {
            assert_eq!(RegionKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(RegionKind::parse("holder"), Some(RegionKind::HolderDome));
        assert_eq!(RegionKind::parse("seq"), Some(RegionKind::Sequential));
        assert_eq!(RegionKind::parse("nope"), None);
    }

    /// `Sequential` must be the Hölder dome at the same couple, bit for
    /// bit — the variant exists for semantic bookkeeping, not to change
    /// the geometry.
    #[test]
    fn sequential_is_the_holder_dome_at_the_same_couple() {
        Runner::new(127).cases(10).run("sequential == holder", |g| {
            let (p, x, ev) = setup(g);
            let hld = SafeRegion::build(RegionKind::HolderDome, &p, &x, &ev);
            let seq = SafeRegion::build(RegionKind::Sequential, &p, &x, &ev);
            if seq.rad().to_bits() != hld.rad().to_bits() {
                return Err("radii differ".to_string());
            }
            for i in 0..p.n() {
                let a = hld.max_abs_inner_stat(
                    p.aty()[i],
                    ev.atr[i],
                    p.col_norms()[i],
                );
                let b = seq.max_abs_inner_stat(
                    p.aty()[i],
                    ev.atr[i],
                    p.col_norms()[i],
                );
                if a.to_bits() != b.to_bits() {
                    return Err(format!("atom {i}: {a} vs {b}"));
                }
            }
            if seq.setup_flops(p.n(), p.m()) != hld.setup_flops(p.n(), p.m())
                || seq.test_flops(p.n()) != hld.test_flops(p.n())
            {
                return Err("flop models differ".to_string());
            }
            Ok(())
        });
    }
}
