//! The Lasso problem, its dual, and the primal-dual machinery of §III.
//!
//! Primal (eq. 1):  `min_x P(x) = ½‖y − Ax‖² + λ‖x‖₁`
//! Dual   (eq. 2):  `max_{u∈U} D(u) = ½‖y‖² − ½‖y − u‖²`,
//!                  `U = {u : ‖Aᵀu‖_∞ ≤ λ}`
//!
//! [`LassoProblem`] owns the instance data plus the per-problem
//! precomputations every solver/screening pass reuses: column norms,
//! `Aᵀy`, `λ_max = ‖Aᵀy‖_∞` (eq. 6) and the FISTA step size `1/‖A‖₂²`.
//!
//! ## Observation-independent vs per-RHS state
//!
//! Those precomputations split cleanly in two:
//!
//! * **dictionary-level** — column norms `‖a_i‖`, stored-structure
//!   nonzero counts, and the spectral norm `‖A‖₂²` depend only on `A`.
//!   They live in a [`SharedDict`]: one immutable [`DictStore`] plus
//!   its caches behind an `Arc`, computed **once** and borrowed by
//!   every solve that shares the dictionary (the serving regime: many
//!   observations, one dictionary — see
//!   [`crate::solver::solve_many`]).
//! * **per-RHS** — `Aᵀy`, `λ_max` and `λ` itself depend on the
//!   observation.  [`LassoProblem`] holds exactly these next to its
//!   `SharedDict` handle, so building the B-th problem over a shared
//!   dictionary costs one `Aᵀy` matvec, not a spectral-norm power
//!   iteration.
//!
//! [`LassoProblem::from_store`] (and [`LassoProblem::new`]) remain the
//! one-shot constructors: they build a private `SharedDict` internally
//! and are bitwise identical to the shared path — sharing is purely an
//! amortization, never a semantic.

use std::sync::{Arc, Mutex};

use crate::linalg::{self, Mat};
use crate::sparse::DictStore;

pub mod cluster;

pub use cluster::{AtomClustering, ClusterHierarchy};

/// Guard value shared with the Python layer (`kernels/ref.py::EPS`).
pub const EPS: f64 = 1e-12;

/// The λ substituted by [`LambdaSpec::resolve`] when the requested λ
/// degenerates to `<= 0` (e.g. `RatioOfMax` on a `y = 0` observation,
/// where `λ_max = 0`).  At this λ the solution is indistinguishable
/// from the least-squares limit and a zero observation solves to
/// `x = 0` in one evaluation.
pub const MIN_LAMBDA: f64 = EPS;

/// How a batched right-hand side picks its regularization level.
///
/// The paper's protocol sets `λ = ratio · λ_max(A, y)` per observation
/// ([`RatioOfMax`](Self::RatioOfMax)); serving traffic with a fixed,
/// externally chosen level uses [`Value`](Self::Value).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LambdaSpec {
    /// An absolute λ.  Non-positive values are clamped to
    /// [`MIN_LAMBDA`] by [`resolve`](Self::resolve).
    Value(f64),
    /// λ as a fraction of this observation's own `λ_max = ‖Aᵀy‖_∞`.
    RatioOfMax(f64),
}

impl LambdaSpec {
    /// The concrete λ for an observation with the given `λ_max`.
    /// Positive results pass through untouched; a degenerate `<= 0`
    /// result (zero observation, non-positive value) is clamped to
    /// [`MIN_LAMBDA`] so [`LassoProblem`]'s `λ > 0` invariant holds.
    pub fn resolve(self, lam_max: f64) -> f64 {
        let lam = match self {
            LambdaSpec::Value(v) => v,
            LambdaSpec::RatioOfMax(r) => r * lam_max,
        };
        if lam > 0.0 {
            lam
        } else {
            MIN_LAMBDA
        }
    }

    /// Request-class label for serving metrics: `"value"` | `"ratio"`.
    /// The streaming session buckets its latency histograms by this
    /// ([`crate::metrics::Registry::observe_classed_secs`]).
    pub fn class_name(self) -> &'static str {
        match self {
            LambdaSpec::Value(_) => "value",
            LambdaSpec::RatioOfMax(_) => "ratio",
        }
    }
}

/// One immutable dictionary plus every observation-independent
/// precomputation, shared across many solves.
///
/// Cloning is an `Arc` bump: a batch of B problems built from one
/// `SharedDict` stores the dictionary, its column norms, its
/// stored-nonzero counts and its spectral-norm estimate **once**,
/// while each problem carries only its own `y`, `Aᵀy`, `λ_max` and λ.
/// The caches are computed by exactly the code the one-shot
/// [`LassoProblem::from_store`] constructor runs, so shared and
/// independent builds of the same matrix are bitwise identical —
/// caches, solver trajectories and [`crate::solver::SolveReport`]s
/// alike (`rust/tests/batch_parity.rs`).
///
/// ```
/// use holder_screening::linalg::Mat;
/// use holder_screening::problem::{LambdaSpec, SharedDict};
/// use holder_screening::sparse::DictStore;
///
/// let a = Mat::from_col_major(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
/// let shared = SharedDict::new(DictStore::Dense(a));
/// // Two problems, one dictionary-level cache set:
/// let p0 = shared.problem(vec![1.0, 0.0], LambdaSpec::RatioOfMax(0.5));
/// let p1 = shared.problem(vec![0.0, 2.0], LambdaSpec::RatioOfMax(0.5));
/// assert!(SharedDict::ptr_eq(p0.shared(), p1.shared()));
/// assert_eq!(p0.lam(), 0.5);
/// assert_eq!(p1.lam(), 1.0);
/// ```
#[derive(Clone, Debug)]
pub struct SharedDict {
    inner: Arc<SharedDictInner>,
}

#[derive(Debug)]
struct SharedDictInner {
    store: DictStore,
    col_norms: Vec<f64>,
    col_nnz: Vec<usize>,
    lipschitz: f64,
    /// Lazily built atom clustering for joint screening
    /// ([`AtomClustering`]); `None` until the first grouped screening
    /// round asks for it, so ungrouped workloads never pay the build.
    /// A `Mutex` rather than a `OnceLock` because a later caller may
    /// ask for a *different* group size (the slot is rebuilt, and the
    /// previous `Arc` stays valid for whoever still holds it).
    clustering: Mutex<Option<Arc<AtomClustering>>>,
    /// Lazily built multi-level clustering for **hierarchical** joint
    /// screening ([`ClusterHierarchy`]), cached beside the flat slot
    /// under the same rebuild-on-size-change discipline (keyed on the
    /// sanitized level-size list).
    hierarchy: Mutex<Option<Arc<ClusterHierarchy>>>,
}

impl SharedDict {
    /// Compute the dictionary-level caches once: column norms, per-
    /// column stored nonzeros, and the power-iteration spectral norm
    /// (the expensive one — 60 matvec pairs on the full dictionary).
    pub fn new(store: DictStore) -> Self {
        let col_norms = store.col_norms();
        let lipschitz = store.spectral_norm_sq(60, 0x5eed).max(EPS);
        let col_nnz = store.col_nnz_counts();
        SharedDict {
            inner: Arc::new(SharedDictInner {
                store,
                col_norms,
                col_nnz,
                lipschitz,
                clustering: Mutex::new(None),
                hierarchy: Mutex::new(None),
            }),
        }
    }

    /// The dictionary storage seam (dense or CSC).
    pub fn store(&self) -> &DictStore {
        &self.inner.store
    }

    /// `m`: observation dimension.
    pub fn rows(&self) -> usize {
        self.inner.store.rows()
    }

    /// `n`: number of atoms.
    pub fn cols(&self) -> usize {
        self.inner.store.cols()
    }

    /// Cached per-atom norms ‖a_i‖₂.
    pub fn col_norms(&self) -> &[f64] {
        &self.inner.col_norms
    }

    /// Stored-structure nonzeros per column (flop-meter weights).
    pub fn col_nnz(&self) -> &[usize] {
        &self.inner.col_nnz
    }

    /// ‖A‖₂² — gradient Lipschitz constant.
    pub fn lipschitz(&self) -> f64 {
        self.inner.lipschitz
    }

    /// The joint-screening atom clustering at this `group_size`,
    /// building (and caching) it on first use.  The clustering depends
    /// only on the dictionary, so every RHS / session / cache hit over
    /// this handle shares one build; repeat calls with the same size
    /// are an `Arc` bump.  Asking for a different size rebuilds the
    /// cached slot — previously returned handles remain valid.
    pub fn clustering(&self, group_size: usize) -> Arc<AtomClustering> {
        let mut slot = self.inner.clustering.lock().unwrap();
        if let Some(c) = slot.as_ref() {
            if c.group_size() == group_size.max(1) {
                return c.clone();
            }
        }
        let built = Arc::new(AtomClustering::build(
            &self.inner.store,
            &self.inner.col_norms,
            group_size,
        ));
        *slot = Some(built.clone());
        built
    }

    /// The hierarchical joint-screening clustering for these level
    /// sizes (coarsest first; sanitized via
    /// [`ClusterHierarchy::sanitize_sizes`]), building and caching it
    /// on first use — the multi-level sibling of
    /// [`clustering`](Self::clustering), under the same contract:
    /// repeat calls with the same (sanitized) sizes are an `Arc` bump,
    /// a different list rebuilds the slot, and previously returned
    /// handles stay valid across the rebuild.
    pub fn hierarchy(&self, sizes: &[usize]) -> Arc<ClusterHierarchy> {
        let want = ClusterHierarchy::sanitize_sizes(sizes);
        let mut slot = self.inner.hierarchy.lock().unwrap();
        if let Some(h) = slot.as_ref() {
            if h.sizes() == want {
                return h.clone();
            }
        }
        let built = Arc::new(ClusterHierarchy::build(
            &self.inner.store,
            &self.inner.col_norms,
            &want,
        ));
        *slot = Some(built.clone());
        built
    }

    /// Build the per-RHS problem for one observation: computes `Aᵀy`
    /// and `λ_max`, resolves `lam`, and borrows (Arc-bumps) everything
    /// dictionary-level.  Equivalent to
    /// [`LassoProblem::from_shared`]`(self, y, lam)`.
    pub fn problem(&self, y: Vec<f64>, lam: LambdaSpec) -> LassoProblem {
        LassoProblem::from_shared(self, y, lam)
    }

    /// Do two handles share one physical dictionary + cache set?
    pub fn ptr_eq(a: &SharedDict, b: &SharedDict) -> bool {
        Arc::ptr_eq(&a.inner, &b.inner)
    }
}

/// A Lasso instance with cached precomputations.
///
/// The dictionary lives behind the [`DictStore`] seam — dense [`Mat`]
/// or sparse CSC — and every precomputation and primal-dual routine
/// dispatches through it, so the two storage formats of the same
/// matrix yield bitwise-identical problems (caches included).
#[derive(Clone, Debug)]
pub struct LassoProblem {
    /// Dictionary + observation-independent caches (Arc-shared; one
    /// physical copy per dictionary, however many RHS solve over it).
    shared: SharedDict,
    y: Vec<f64>,
    lam: f64,
    // --- per-RHS cached ---
    aty: Vec<f64>,
    lam_max: f64,
}

impl LassoProblem {
    /// Build a problem from a dense dictionary (columns = atoms).
    ///
    /// Panics if shapes disagree or `lam <= 0`.
    pub fn new(a: Mat, y: Vec<f64>, lam: f64) -> Self {
        Self::from_store(DictStore::Dense(a), y, lam)
    }

    /// Build a problem from either dictionary backend, computing every
    /// cache (a private [`SharedDict`] plus the per-RHS `Aᵀy`/`λ_max`).
    pub fn from_store(store: DictStore, y: Vec<f64>, lam: f64) -> Self {
        assert!(lam > 0.0, "lambda must be positive");
        Self::from_shared(&SharedDict::new(store), y, LambdaSpec::Value(lam))
    }

    /// Build the per-RHS problem over an existing [`SharedDict`]: only
    /// `Aᵀy` and `λ_max` are computed; the dictionary-level caches are
    /// borrowed.  Bitwise identical to [`from_store`](Self::from_store)
    /// of the same matrix, observation and resolved λ.
    pub fn from_shared(
        shared: &SharedDict,
        y: Vec<f64>,
        lam: LambdaSpec,
    ) -> Self {
        assert_eq!(shared.rows(), y.len(), "A rows must match y length");
        let mut aty = vec![0.0; shared.cols()];
        shared.store().gemv_t(&y, &mut aty);
        let lam_max = linalg::norm_inf(&aty);
        let lam = lam.resolve(lam_max);
        LassoProblem { shared: shared.clone(), y, lam, aty, lam_max }
    }

    /// Same instance at a different λ (path solving; caches are reused).
    pub fn with_lambda(&self, lam: f64) -> Self {
        assert!(lam > 0.0);
        let mut p = self.clone();
        p.lam = lam;
        p
    }

    // --- accessors ---

    /// The dense dictionary backend.  Panics for CSC-backed problems —
    /// storage-agnostic code goes through [`store`](Self::store).
    pub fn a(&self) -> &Mat {
        self.shared.store().as_dense().expect(
            "LassoProblem::a(): dense dictionary required; \
             this problem is CSC-backed — dispatch through store()",
        )
    }
    /// The dictionary storage seam (dense or CSC).
    pub fn store(&self) -> &DictStore {
        self.shared.store()
    }
    /// The shared dictionary handle (Arc-bump to reuse it for more
    /// observations — see [`crate::solver::solve_many`]).
    pub fn shared(&self) -> &SharedDict {
        &self.shared
    }
    /// Stored-structure nonzeros per column (flop-meter weights).
    pub fn col_nnz(&self) -> &[usize] {
        self.shared.col_nnz()
    }
    pub fn y(&self) -> &[f64] {
        &self.y
    }
    pub fn lam(&self) -> f64 {
        self.lam
    }
    /// `m`: observation dimension.
    pub fn m(&self) -> usize {
        self.shared.rows()
    }
    /// `n`: number of atoms.
    pub fn n(&self) -> usize {
        self.shared.cols()
    }
    /// Cached per-atom norms ‖a_i‖₂.
    pub fn col_norms(&self) -> &[f64] {
        self.shared.col_norms()
    }
    /// Cached `Aᵀ y`.
    pub fn aty(&self) -> &[f64] {
        &self.aty
    }
    /// `λ_max = ‖Aᵀy‖_∞` (eq. 6): smallest λ with 0 as unique solution.
    pub fn lam_max(&self) -> f64 {
        self.lam_max
    }
    /// ‖A‖₂² — gradient Lipschitz constant.
    pub fn lipschitz(&self) -> f64 {
        self.shared.lipschitz()
    }
    /// The standard FISTA step `1/‖A‖₂²`, with a 1% safety margin since
    /// the power iteration estimates the spectral norm from below.
    pub fn default_step(&self) -> f64 {
        1.0 / (self.lipschitz() * 1.01)
    }

    // --- primal/dual machinery ---

    /// Residual `r = y − Ax`.
    pub fn residual(&self, x: &[f64], out: &mut [f64]) {
        self.shared.store().gemv(x, out);
        for (o, yi) in out.iter_mut().zip(&self.y) {
            *o = yi - *o;
        }
    }

    /// Primal objective `P(x)` (eq. 1).
    pub fn primal(&self, x: &[f64]) -> f64 {
        let mut r = vec![0.0; self.m()];
        self.residual(x, &mut r);
        0.5 * linalg::norm2_sq(&r) + self.lam * linalg::norm1(x)
    }

    /// Primal objective from a precomputed residual (hot path).
    pub fn primal_from_residual(&self, x: &[f64], r: &[f64]) -> f64 {
        0.5 * linalg::norm2_sq(r) + self.lam * linalg::norm1(x)
    }

    /// Dual objective `D(u)` (eq. 2).
    pub fn dual(&self, u: &[f64]) -> f64 {
        let mut diff = vec![0.0; self.m()];
        linalg::sub(&self.y, u, &mut diff);
        0.5 * linalg::norm2_sq(&self.y) - 0.5 * linalg::norm2_sq(&diff)
    }

    /// Is `u` dual feasible (`‖Aᵀu‖_∞ ≤ λ(1+tol)`)?
    pub fn is_dual_feasible(&self, u: &[f64], tol: f64) -> bool {
        let mut atu = vec![0.0; self.n()];
        self.shared.store().gemv_t(u, &mut atu);
        linalg::norm_inf(&atu) <= self.lam * (1.0 + tol)
    }

    /// Dual scaling of a residual (El Ghaoui §3.3): `u = s·r` with
    /// `s = min(1, λ/‖Aᵀr‖_∞)`.  Returns (u, s).  `atr` is `Aᵀr`.
    pub fn dual_scale(&self, r: &[f64], atr: &[f64]) -> (Vec<f64>, f64) {
        let corr = linalg::norm_inf(atr);
        let s = (self.lam / corr.max(EPS)).min(1.0);
        let mut u = r.to_vec();
        linalg::scale(&mut u, s);
        (u, s)
    }

    /// Duality gap `P(x) − D(u)` (eq. 3); clamped at 0 to absorb
    /// floating-point noise near optimality.
    pub fn gap(&self, x: &[f64], u: &[f64]) -> f64 {
        (self.primal(x) - self.dual(u)).max(0.0)
    }

    /// Full primal-dual evaluation at `x`: residual → dual scaling →
    /// gap.  Returns [`PrimalDualEval`].  This is the reference
    /// (unmetered) implementation; the solver has a fused, flop-charged
    /// version.
    pub fn eval(&self, x: &[f64]) -> PrimalDualEval {
        let mut r = vec![0.0; self.m()];
        self.residual(x, &mut r);
        let mut atr = vec![0.0; self.n()];
        self.shared.store().gemv_t(&r, &mut atr);
        let (u, scale) = self.dual_scale(&r, &atr);
        let p = self.primal_from_residual(x, &r);
        let d = self.dual(&u);
        PrimalDualEval { p, d, gap: (p - d).max(0.0), u, r, atr, scale }
    }
}

/// The result of a primal-dual evaluation at some `x`.
#[derive(Clone, Debug)]
pub struct PrimalDualEval {
    pub p: f64,
    pub d: f64,
    pub gap: f64,
    /// Feasible dual point (rescaled residual).
    pub u: Vec<f64>,
    /// Residual `y − Ax`.
    pub r: Vec<f64>,
    /// `Aᵀ r` (reused by screening).
    pub atr: Vec<f64>,
    /// The dual-scaling factor `s`.
    pub scale: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemv, gemv_t};
    use crate::proptest::{Gen, Runner};

    fn small_problem(seed: u64) -> LassoProblem {
        let mut g = Gen::for_case(seed, 0);
        let a = g.dictionary(20, 50);
        let y = g.observation(20);
        let mut aty = vec![0.0; 50];
        gemv_t(&a, &y, &mut aty);
        let lam = 0.5 * linalg::norm_inf(&aty);
        LassoProblem::new(a, y, lam)
    }

    #[test]
    fn primal_at_zero_is_half_y_norm() {
        let p = small_problem(1);
        let x = vec![0.0; p.n()];
        // y on unit sphere => P(0) = 1/2
        assert!((p.primal(&x) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lam_max_gives_zero_solution_certificate() {
        let p = small_problem(2);
        // At lam >= lam_max, u = y is dual feasible and gap(0, y) = 0.
        let p2 = p.with_lambda(p.lam_max() * 1.0000001);
        assert!(p2.is_dual_feasible(p2.y(), 1e-9));
        let x0 = vec![0.0; p2.n()];
        assert!(p2.gap(&x0, p2.y()) < 1e-9);
    }

    #[test]
    fn dual_scale_feasible_property() {
        Runner::new(42).cases(50).run("dual scaling feasible", |g| {
            let m = g.usize_in(3, 30);
            let n = g.usize_in(2, 60);
            let a = g.dictionary(m, n);
            let y = g.observation(m);
            let mut aty = vec![0.0; n];
            gemv_t(&a, &y, &mut aty);
            let lam_max = linalg::norm_inf(&aty);
            if lam_max < 1e-9 {
                return Ok(());
            }
            let lam = g.f64_in(0.1, 1.0) * lam_max;
            let p = LassoProblem::new(a, y, lam);
            let x = g.vec_sparse(n, n / 3 + 1);
            let ev = p.eval(&x);
            if !p.is_dual_feasible(&ev.u, 1e-9) {
                return Err("scaled dual point infeasible".into());
            }
            Ok(())
        });
    }

    #[test]
    fn weak_duality_property() {
        Runner::new(43).cases(50).run("gap nonnegative", |g| {
            let m = g.usize_in(3, 25);
            let n = g.usize_in(2, 50);
            let a = g.dictionary(m, n);
            let y = g.observation(m);
            let mut aty = vec![0.0; n];
            gemv_t(&a, &y, &mut aty);
            let lam_max = linalg::norm_inf(&aty);
            if lam_max < 1e-9 {
                return Ok(());
            }
            let p = LassoProblem::new(a, y, 0.4 * lam_max);
            let x = g.vec_sparse(n, 3);
            let ev = p.eval(&x);
            // raw (unclamped) gap must be >= -eps
            if ev.p - ev.d < -1e-9 {
                return Err(format!("negative gap {}", ev.p - ev.d));
            }
            Ok(())
        });
    }

    #[test]
    fn eval_consistency() {
        let p = small_problem(3);
        let mut g = Gen::for_case(99, 0);
        let x = g.vec_sparse(p.n(), 5);
        let ev = p.eval(&x);
        assert!((ev.p - p.primal(&x)).abs() < 1e-10);
        assert!((ev.d - p.dual(&ev.u)).abs() < 1e-10);
        assert!((ev.gap - p.gap(&x, &ev.u)).abs() < 1e-10);
        // residual identity
        let mut r = vec![0.0; p.m()];
        p.residual(&x, &mut r);
        assert!(linalg::max_abs_diff(&r, &ev.r) < 1e-12);
    }

    #[test]
    fn lipschitz_bounds_gradient() {
        // ‖AᵀA x‖ <= L ‖x‖ for the computed L (power-iteration result).
        let p = small_problem(4);
        let mut g = Gen::for_case(7, 0);
        let x = g.vec_normal(p.n());
        let mut ax = vec![0.0; p.m()];
        gemv(p.a(), &x, &mut ax);
        let mut atax = vec![0.0; p.n()];
        gemv_t(p.a(), &ax, &mut atax);
        let ratio = linalg::norm2(&atax) / linalg::norm2(&x);
        assert!(ratio <= p.lipschitz() * 1.001, "{ratio} vs {}", p.lipschitz());
    }

    #[test]
    #[should_panic]
    fn negative_lambda_panics() {
        let mut g = Gen::for_case(0, 0);
        let a = g.dictionary(4, 6);
        let y = g.observation(4);
        LassoProblem::new(a, y, -1.0);
    }

    #[test]
    fn lambda_spec_resolution() {
        assert_eq!(LambdaSpec::Value(0.7).resolve(123.0), 0.7);
        assert_eq!(LambdaSpec::RatioOfMax(0.5).resolve(2.0), 1.0);
        // Degenerate specs clamp to MIN_LAMBDA instead of panicking.
        assert_eq!(LambdaSpec::RatioOfMax(0.5).resolve(0.0), MIN_LAMBDA);
        assert_eq!(LambdaSpec::Value(0.0).resolve(1.0), MIN_LAMBDA);
        assert_eq!(LambdaSpec::Value(-3.0).resolve(1.0), MIN_LAMBDA);
    }

    #[test]
    fn lambda_spec_class_names() {
        assert_eq!(LambdaSpec::Value(0.7).class_name(), "value");
        assert_eq!(LambdaSpec::RatioOfMax(0.5).class_name(), "ratio");
    }

    /// A shared build must be bitwise the one-shot build: same caches,
    /// same λ, same primal-dual evaluations.
    #[test]
    fn shared_build_bitwise_matches_from_store() {
        let mut g = Gen::for_case(9, 0);
        let a = g.dictionary(15, 40);
        let y = g.observation(15);
        let solo = LassoProblem::new(a.clone(), y.clone(), 0.3);
        let shared = SharedDict::new(DictStore::Dense(a));
        let p = shared.problem(y, LambdaSpec::Value(0.3));
        assert_eq!(solo.lam().to_bits(), p.lam().to_bits());
        assert_eq!(solo.lam_max().to_bits(), p.lam_max().to_bits());
        assert_eq!(solo.lipschitz().to_bits(), p.lipschitz().to_bits());
        assert_eq!(solo.col_nnz(), p.col_nnz());
        for (s, v) in solo.col_norms().iter().zip(p.col_norms()) {
            assert_eq!(s.to_bits(), v.to_bits());
        }
        for (s, v) in solo.aty().iter().zip(p.aty()) {
            assert_eq!(s.to_bits(), v.to_bits());
        }
    }

    /// Problems built over one handle share the physical dictionary;
    /// `with_lambda` and `clone` keep sharing it (Arc bumps, no copy).
    #[test]
    fn shared_handle_survives_clone_and_with_lambda() {
        let p = small_problem(11);
        let shared = p.shared().clone();
        assert!(SharedDict::ptr_eq(p.shared(), &shared));
        let p2 = p.with_lambda(p.lam() * 0.5);
        assert!(SharedDict::ptr_eq(p2.shared(), &shared));
        let p3 = shared.problem(p.y().to_vec(), LambdaSpec::RatioOfMax(0.4));
        assert!(SharedDict::ptr_eq(p3.shared(), &shared));
        assert!((p3.lam() / p3.lam_max() - 0.4).abs() < 1e-12);
    }

    /// The y = 0 degenerate batch member: λ_max = 0, λ clamps to
    /// MIN_LAMBDA, and x = 0 is optimal with gap 0 at the start.
    #[test]
    fn zero_observation_is_well_posed() {
        let mut g = Gen::for_case(12, 0);
        let a = g.dictionary(8, 20);
        let shared = SharedDict::new(DictStore::Dense(a));
        let p = shared.problem(vec![0.0; 8], LambdaSpec::RatioOfMax(0.5));
        assert_eq!(p.lam(), MIN_LAMBDA);
        assert_eq!(p.lam_max(), 0.0);
        let x0 = vec![0.0; p.n()];
        let ev = p.eval(&x0);
        assert_eq!(ev.gap, 0.0);
    }

    /// The lazy clustering cache: same size is an Arc bump, a new size
    /// rebuilds, and old handles stay valid across the rebuild.
    #[test]
    fn clustering_cache_reuses_and_rebuilds() {
        let mut g = Gen::for_case(13, 0);
        let a = g.dictionary(10, 40);
        let shared = SharedDict::new(DictStore::Dense(a));
        let c8 = shared.clustering(8);
        let c8b = shared.clustering(8);
        assert!(Arc::ptr_eq(&c8, &c8b), "same size must reuse the build");
        assert_eq!(c8.group_size(), 8);
        assert_eq!(c8.num_groups(), 5);
        let c16 = shared.clustering(16);
        assert_eq!(c16.group_size(), 16);
        assert!(!Arc::ptr_eq(&c8, &c16));
        // the old handle still answers after the slot was rebuilt
        assert_eq!(c8.num_groups(), 5);
    }

    /// The hierarchy cache: same (sanitized) sizes reuse the build —
    /// including permutations that sanitize to the same list — a new
    /// list rebuilds, and old handles survive.
    #[test]
    fn hierarchy_cache_reuses_and_rebuilds() {
        let mut g = Gen::for_case(14, 0);
        let a = g.dictionary(10, 64);
        let shared = SharedDict::new(DictStore::Dense(a));
        let h = shared.hierarchy(&[16, 4]);
        assert_eq!(h.sizes(), vec![16, 4]);
        let h2 = shared.hierarchy(&[4, 16]); // sanitizes identically
        assert!(Arc::ptr_eq(&h, &h2), "same sanitized sizes must reuse");
        let h3 = shared.hierarchy(&[32, 8]);
        assert_eq!(h3.sizes(), vec![32, 8]);
        assert!(!Arc::ptr_eq(&h, &h3));
        // the old handle still answers after the rebuild
        assert_eq!(h.levels().len(), 2);
        // the flat clustering slot is untouched by hierarchy builds
        let c = shared.clustering(16);
        assert_eq!(c.group_size(), 16);
    }
}
