//! Atom clustering for joint (group) screening tests.
//!
//! Herzet & Drémeau's *Joint Screening Tests for LASSO* replace n
//! per-atom tests with one test per **ball of atoms**: if
//! `sup_{a ∈ B(c_g, r_g)} sup_{u ∈ R} ⟨a, u⟩ < λ`, every atom inside
//! the ball is screened by a single bound evaluation.  This module
//! holds the dictionary-side half of that idea — which atoms form a
//! ball, and how big it is:
//!
//! * **Groups are contiguous index blocks** of `group_size` atoms
//!   (`group_of(j) = j / group_size`).  For the truncated-pulse
//!   Toeplitz family the atom at column `j` is a pulse centred at
//!   `j·m/n`, so neighboring indices are neighboring shifts and blocks
//!   are natural clusters; for unstructured (Gaussian) dictionaries
//!   the radii come out near `√2` and the group tests simply never
//!   fire — grouping degrades to the flat pass, it never hurts safety.
//! * **The representative is an actual member atom** (the first of the
//!   block), not a centroid: `dist_to_rep[rep] = 0` exactly, and the
//!   radius is `max_i ‖a_i − a_rep‖` over the block.
//! * **Distances are computed from explicit column differences**
//!   (densified out of either [`DictStore`] backend), *not* from the
//!   cancellation-prone `‖a_i‖² − 2⟨a_i,c⟩ + ‖c‖²` identity, and then
//!   inflated by a worst-case rounding envelope ([`dist_upper`]).  The
//!   stored distances are therefore certified **upper** bounds on the
//!   true distances — the conservative direction for a safe test.
//!
//! The clustering depends only on the dictionary, so it is computed
//! once and cached inside [`crate::problem::SharedDict`] (lazily, on
//! the first grouped screening round) and amortized across every RHS,
//! session and cache hit that shares the store.
//!
//! ## Hierarchies
//!
//! A [`ClusterHierarchy`] stacks 2–3 clusterings of strictly
//! decreasing group size (e.g. 1024 → 64 → atom): one coarse test can
//! certify a thousand atoms at once, and a failed coarse test
//! *descends* to the finer level instead of falling straight through
//! to per-atom work.  Every level is an ordinary [`AtomClustering`] —
//! same contiguous blocks, same certified-upper-bound radii and
//! member→rep distances — so the safety/dominance argument of the flat
//! grouped pass applies level by level, unchanged.

use crate::sparse::DictStore;

/// Precomputed fixed-size atom clustering (see the module docs).
#[derive(Clone, Debug)]
pub struct AtomClustering {
    group_size: usize,
    n: usize,
    /// Per-group representative atom index (first member).
    reps: Vec<usize>,
    /// Per-group certified radius `max_i ‖a_i − a_rep‖` (upper bound).
    radius: Vec<f64>,
    /// Per-atom certified distance `‖a_j − a_rep(group_of(j))‖`
    /// (upper bound), indexed by original atom index.
    dist_to_rep: Vec<f64>,
}

/// Certified upper bound on the true distance given the computed one.
///
/// `d2` is `Σ_i (a_i − c_i)²` accumulated left-to-right in f64.  Each
/// difference carries relative error ≤ ε, each square and add another;
/// bounding the accumulated error by `2ε·d·(‖a‖+‖c‖) + (m+2)ε·d²` and
/// dividing by `2d` gives a distance error at most
/// `ε·(‖a‖+‖c‖) + mε·d`.  We inflate by double that envelope so the
/// stored value provably dominates the exact distance — a few parts in
/// 10¹³ for unit atoms, invisible next to any real cluster radius.
fn dist_upper(d2: f64, m: usize, norm_a: f64, norm_c: f64) -> f64 {
    let d = d2.max(0.0).sqrt();
    let eps = f64::EPSILON;
    d * (1.0 + 2.0 * m as f64 * eps) + 2.0 * eps * (norm_a + norm_c)
}

/// Scatter column `j` of either backend into the dense scratch `out`.
fn densify_col(store: &DictStore, j: usize, out: &mut [f64]) {
    match store {
        DictStore::Dense(a) => out.copy_from_slice(a.col(j)),
        DictStore::Csc(c) => {
            out.fill(0.0);
            let (rows, vals) = c.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                out[i as usize] = v;
            }
        }
    }
}

impl AtomClustering {
    /// Cluster the dictionary into contiguous blocks of `group_size`
    /// atoms (clamped to ≥ 1).  Cost: one densified column pass per
    /// atom — `O(n·m)` worst case, once per dictionary.
    pub fn build(store: &DictStore, col_norms: &[f64], group_size: usize) -> Self {
        let n = store.cols();
        let m = store.rows();
        let group_size = group_size.max(1);
        let num_groups = n.div_ceil(group_size);
        let mut reps = Vec::with_capacity(num_groups);
        let mut radius = vec![0.0; num_groups];
        let mut dist_to_rep = vec![0.0; n];
        let mut rep_col = vec![0.0; m];
        let mut member_col = vec![0.0; m];
        for g in 0..num_groups {
            let start = g * group_size;
            let end = ((g + 1) * group_size).min(n);
            let rep = start;
            reps.push(rep);
            densify_col(store, rep, &mut rep_col);
            for j in (start + 1)..end {
                densify_col(store, j, &mut member_col);
                let mut d2 = 0.0;
                for (&a, &c) in member_col.iter().zip(&rep_col) {
                    let t = a - c;
                    d2 += t * t;
                }
                let d = dist_upper(d2, m, col_norms[j], col_norms[rep]);
                dist_to_rep[j] = d;
                if d > radius[g] {
                    radius[g] = d;
                }
            }
        }
        AtomClustering { group_size, n, reps, radius, dist_to_rep }
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// Number of atoms clustered.
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn num_groups(&self) -> usize {
        self.reps.len()
    }

    /// The group that atom `j` belongs to.
    #[inline]
    pub fn group_of(&self, j: usize) -> usize {
        j / self.group_size
    }

    /// Representative atom index of group `g`.
    pub fn rep(&self, g: usize) -> usize {
        self.reps[g]
    }

    /// Certified ball radius of group `g` (`max_i ‖a_i − a_rep‖`,
    /// rounded **up** — see the module docs).
    #[inline]
    pub fn radius(&self, g: usize) -> f64 {
        self.radius[g]
    }

    /// Certified `‖a_j − a_rep‖` for atom `j` (rounded **up**).
    ///
    /// Triangle inequality: for any two members `i`, `p` of one group,
    /// `‖a_i − a_p‖ ≤ dist_to_rep(i) + dist_to_rep(p)
    ///             ≤ radius(g) + dist_to_rep(p)` —
    /// which is what lets the screening engine pivot a group test on
    /// **any active member**, not just the (possibly screened)
    /// representative.
    #[inline]
    pub fn dist_to_rep(&self, j: usize) -> f64 {
        self.dist_to_rep[j]
    }

    /// Member index range of group `g`.
    pub fn members(&self, g: usize) -> std::ops::Range<usize> {
        let start = g * self.group_size;
        start..((g + 1) * self.group_size).min(self.n)
    }
}

/// A coarse-to-fine stack of [`AtomClustering`]s for hierarchical
/// joint screening (see the module docs).  Level 0 is the coarsest;
/// the implicit final level is the per-atom test.
///
/// Levels are held behind `Arc` so the screening engine can hold a
/// handle per solve while [`crate::problem::SharedDict`] keeps the
/// build cached across every RHS sharing the dictionary.
#[derive(Clone, Debug)]
pub struct ClusterHierarchy {
    levels: Vec<std::sync::Arc<AtomClustering>>,
}

impl ClusterHierarchy {
    /// Sanitize a requested level-size list: clamp each to ≥ 1, sort
    /// descending, drop duplicates, and cap at
    /// [`crate::screening::MAX_GROUP_LEVELS`] (keeping the finest
    /// sizes, whose tests are the cheapest to waste).  The result is
    /// strictly decreasing and non-empty whenever the input held any
    /// positive size; an empty input yields an empty list (grouping
    /// disabled upstream).
    pub fn sanitize_sizes(sizes: &[usize]) -> Vec<usize> {
        let mut s: Vec<usize> =
            sizes.iter().map(|&v| v.max(1)).collect();
        s.sort_unstable_by(|a, b| b.cmp(a));
        s.dedup();
        let max = crate::screening::MAX_GROUP_LEVELS;
        if s.len() > max {
            s.drain(..s.len() - max);
        }
        s
    }

    /// Build one [`AtomClustering`] per (sanitized) level size —
    /// coarse to fine.  Cost: one densified column pass per atom per
    /// level, once per dictionary.
    pub fn build(
        store: &DictStore,
        col_norms: &[f64],
        sizes: &[usize],
    ) -> Self {
        let levels = Self::sanitize_sizes(sizes)
            .into_iter()
            .map(|gs| {
                std::sync::Arc::new(AtomClustering::build(
                    store, col_norms, gs,
                ))
            })
            .collect();
        ClusterHierarchy { levels }
    }

    /// The per-level clusterings, coarsest first.
    pub fn levels(&self) -> &[std::sync::Arc<AtomClustering>] {
        &self.levels
    }

    /// Group sizes, coarsest first (strictly decreasing).
    pub fn sizes(&self) -> Vec<usize> {
        self.levels.iter().map(|c| c.group_size()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;
    use crate::proptest::Gen;
    use crate::sparse::CscMat;

    fn dict(seed: u64, m: usize, n: usize) -> (DictStore, Vec<f64>) {
        let mut g = Gen::for_case(seed, 0);
        let a = g.dictionary(m, n);
        let store = DictStore::Dense(a);
        let norms = store.col_norms();
        (store, norms)
    }

    #[test]
    fn distances_dominate_true_distances() {
        let (store, norms) = dict(31, 12, 40);
        let c = AtomClustering::build(&store, &norms, 8);
        let a = store.as_dense().unwrap();
        for g in 0..c.num_groups() {
            let rep = c.rep(g);
            for j in c.members(g) {
                let diff: Vec<f64> = a
                    .col(j)
                    .iter()
                    .zip(a.col(rep))
                    .map(|(x, y)| x - y)
                    .collect();
                let true_d = linalg::norm2(&diff);
                assert!(
                    c.dist_to_rep(j) >= true_d,
                    "atom {j}: stored {} < true {true_d}",
                    c.dist_to_rep(j)
                );
                assert!(c.radius(g) >= c.dist_to_rep(j));
            }
        }
    }

    #[test]
    fn rep_distance_is_exactly_zero() {
        let (store, norms) = dict(32, 10, 30);
        let c = AtomClustering::build(&store, &norms, 7);
        for g in 0..c.num_groups() {
            assert_eq!(c.dist_to_rep(c.rep(g)), 0.0);
        }
    }

    #[test]
    fn degenerate_group_sizes() {
        let (store, norms) = dict(33, 9, 25);
        // n groups of 1: every radius is 0.
        let singles = AtomClustering::build(&store, &norms, 1);
        assert_eq!(singles.num_groups(), 25);
        for g in 0..25 {
            assert_eq!(singles.radius(g), 0.0);
            assert_eq!(singles.members(g).len(), 1);
        }
        // 1 group of n (group_size > n clamps the block to n members).
        let one = AtomClustering::build(&store, &norms, 100);
        assert_eq!(one.num_groups(), 1);
        assert_eq!(one.members(0), 0..25);
        // group_size 0 clamps to 1 instead of dividing by zero.
        let clamped = AtomClustering::build(&store, &norms, 0);
        assert_eq!(clamped.group_size(), 1);
    }

    #[test]
    fn hierarchy_sanitizes_and_orders_levels() {
        // Unordered, duplicated, zero-containing input comes out
        // strictly decreasing, clamped and capped.
        assert_eq!(
            ClusterHierarchy::sanitize_sizes(&[64, 1024, 64, 0]),
            vec![1024, 64, 1]
        );
        assert_eq!(
            ClusterHierarchy::sanitize_sizes(&[8, 512, 64, 4096, 1024]),
            vec![512, 64, 8] // capped at MAX_GROUP_LEVELS finest sizes
        );
        assert_eq!(ClusterHierarchy::sanitize_sizes(&[]), Vec::<usize>::new());
        let (store, norms) = dict(35, 10, 50);
        let h = ClusterHierarchy::build(&store, &norms, &[16, 4]);
        assert_eq!(h.sizes(), vec![16, 4]);
        assert_eq!(h.levels().len(), 2);
        assert_eq!(h.levels()[0].group_size(), 16);
        assert_eq!(h.levels()[1].group_size(), 4);
        assert_eq!(h.levels()[0].num_groups(), 4);
        assert_eq!(h.levels()[1].num_groups(), 13);
    }

    #[test]
    fn hierarchy_levels_match_standalone_clusterings_bitwise() {
        // Each level must be exactly the flat clustering at that size —
        // the hierarchy adds structure, never different arithmetic.
        let (store, norms) = dict(36, 11, 41);
        let h = ClusterHierarchy::build(&store, &norms, &[12, 3]);
        for level in h.levels() {
            let flat =
                AtomClustering::build(&store, &norms, level.group_size());
            assert_eq!(level.num_groups(), flat.num_groups());
            for j in 0..41 {
                assert_eq!(
                    level.dist_to_rep(j).to_bits(),
                    flat.dist_to_rep(j).to_bits()
                );
            }
            for g in 0..flat.num_groups() {
                assert_eq!(
                    level.radius(g).to_bits(),
                    flat.radius(g).to_bits()
                );
                assert_eq!(level.rep(g), flat.rep(g));
            }
        }
    }

    #[test]
    fn csc_build_matches_dense_build_bitwise() {
        let mut g = Gen::for_case(34, 0);
        let a = g.dictionary(11, 33);
        let dense = DictStore::Dense(a.clone());
        let csc = DictStore::Csc(CscMat::from_dense(&a));
        let norms = dense.col_norms();
        let cd = AtomClustering::build(&dense, &norms, 6);
        let cc = AtomClustering::build(&csc, &norms, 6);
        assert_eq!(cd.num_groups(), cc.num_groups());
        for j in 0..33 {
            assert_eq!(
                cd.dist_to_rep(j).to_bits(),
                cc.dist_to_rep(j).to_bits(),
                "atom {j}"
            );
        }
        for g in 0..cd.num_groups() {
            assert_eq!(cd.radius(g).to_bits(), cc.radius(g).to_bits());
        }
    }
}
