//! Dictionary / instance generators reproducing the paper's §V setup.
//!
//! * `y` drawn uniformly on the unit sphere `S^{m-1}`;
//! * `A` either (i) i.i.d. `N(0,1)` entries, or (ii) a Toeplitz
//!   structure whose columns are shifted samples of a Gaussian curve
//!   (a convolutional dictionary — the sparse-deconvolution workload);
//! * columns normalized to `‖a_i‖₂ = 1`;
//! * `λ = ratio · λ_max` with `ratio ∈ {0.3, 0.5, 0.8}` in the paper.

use crate::linalg::{self, Mat};
use crate::problem::{LassoProblem, SharedDict};
use crate::sparse::{CscMat, DictFormat, DictStore};
use crate::util::rng::Pcg64;

/// Which dictionary family to draw (paper §V).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DictKind {
    /// i.i.d. standard-normal entries, normalized columns.
    Gaussian,
    /// Toeplitz: column `j` is a Gaussian pulse centred at row
    /// `j·m/n` (cyclically shifted), normalized.  Adjacent atoms are
    /// highly correlated — the hard case for screening.
    Toeplitz,
}

impl DictKind {
    pub fn parse(s: &str) -> Option<DictKind> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "iid" | "normal" => Some(DictKind::Gaussian),
            "toeplitz" | "conv" | "convolutional" => Some(DictKind::Toeplitz),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DictKind::Gaussian => "gaussian",
            DictKind::Toeplitz => "toeplitz",
        }
    }
}

/// Instance-generation configuration.
#[derive(Clone, Debug)]
pub struct InstanceConfig {
    pub m: usize,
    pub n: usize,
    pub kind: DictKind,
    /// λ as a fraction of λ_max (paper: 0.3 / 0.5 / 0.8).
    pub lam_ratio: f64,
    /// Width (std dev, in rows) of the Toeplitz Gaussian pulse.
    pub pulse_width: f64,
    /// Truncate the Toeplitz pulse at this many standard deviations:
    /// entries with cyclic distance `> pulse_cutoff · pulse_width`
    /// become **exact zeros** (in both storage formats, so dense and
    /// CSC draws of one config are the same matrix bit for bit).
    /// `0.0` disables truncation — the pre-existing dense pulse.
    pub pulse_cutoff: f64,
    /// Storage format of the drawn dictionary (CLI `--dict-format`).
    pub format: DictFormat,
}

impl Default for InstanceConfig {
    fn default() -> Self {
        InstanceConfig {
            m: 100,
            n: 500,
            kind: DictKind::Gaussian,
            lam_ratio: 0.5,
            pulse_width: 4.0,
            pulse_cutoff: 0.0,
            format: DictFormat::Dense,
        }
    }
}

impl InstanceConfig {
    /// The paper's base setup: (m, n) = (100, 500).
    pub fn paper(kind: DictKind, lam_ratio: f64) -> Self {
        InstanceConfig { kind, lam_ratio, ..Default::default() }
    }
}

/// A generated instance: problem + provenance.
#[derive(Clone, Debug)]
pub struct Instance {
    pub problem: LassoProblem,
    pub config: InstanceConfig,
    pub seed: u64,
}

/// Draw the dictionary matrix only (unnormalized-then-normalized).
/// A positive `pulse_cutoff` (in pulse standard deviations) truncates
/// the Toeplitz pulse to exact zeros — the dense twin of the CSC
/// draw, entry for entry.
pub fn draw_dictionary(
    kind: DictKind,
    m: usize,
    n: usize,
    pulse_width: f64,
    pulse_cutoff: f64,
    rng: &mut Pcg64,
) -> Mat {
    let mut a = match kind {
        DictKind::Gaussian => {
            let mut mat = Mat::zeros(m, n);
            for j in 0..n {
                for v in mat.col_mut(j) {
                    *v = rng.normal();
                }
            }
            mat
        }
        DictKind::Toeplitz => {
            let mut mat = Mat::zeros(m, n);
            let w2 = 2.0 * pulse_width * pulse_width;
            let lim = toeplitz_limit(pulse_width, pulse_cutoff);
            for j in 0..n {
                // Pulse centre moves linearly through the rows so the
                // atoms tile the observation window (cyclic wrap).
                let centre = (j as f64) * (m as f64) / (n as f64);
                let col = mat.col_mut(j);
                for (i, v) in col.iter_mut().enumerate() {
                    // cyclic distance
                    let mut d = (i as f64 - centre).abs();
                    d = d.min(m as f64 - d);
                    *v = if d <= lim { (-d * d / w2).exp() } else { 0.0 };
                }
            }
            mat
        }
    };
    a.normalize_columns();
    a
}

/// Truncation radius in rows (`∞` when the cutoff is disabled).
fn toeplitz_limit(pulse_width: f64, pulse_cutoff: f64) -> f64 {
    if pulse_cutoff > 0.0 {
        pulse_cutoff * pulse_width
    } else {
        f64::INFINITY
    }
}

/// Draw the dictionary in the requested storage format.
///
/// * `Dense` — the [`draw_dictionary`] matrix, wrapped.
/// * `Csc` + `Toeplitz` — built **directly** in CSC: only the rows
///   inside the truncation window are visited/stored, normalized with
///   the dense-replay sparse norm, so the result is bitwise the
///   dense draw's nonzero structure without ever materializing `m × n`
///   storage.
/// * `Csc` + `Gaussian` — dense draw (same RNG stream) through the
///   dense→CSC converter.
pub fn draw_dictionary_store(
    kind: DictKind,
    m: usize,
    n: usize,
    pulse_width: f64,
    pulse_cutoff: f64,
    format: DictFormat,
    rng: &mut Pcg64,
) -> DictStore {
    match (format, kind) {
        (DictFormat::Dense, _) => DictStore::Dense(draw_dictionary(
            kind,
            m,
            n,
            pulse_width,
            pulse_cutoff,
            rng,
        )),
        (DictFormat::Csc, DictKind::Gaussian) => {
            DictStore::Csc(CscMat::from_dense(&draw_dictionary(
                kind,
                m,
                n,
                pulse_width,
                pulse_cutoff,
                rng,
            )))
        }
        (DictFormat::Csc, DictKind::Toeplitz) => DictStore::Csc(
            draw_toeplitz_csc(m, n, pulse_width, pulse_cutoff),
        ),
    }
}

/// Direct CSC build of the truncated Toeplitz pulse dictionary.
///
/// Every stored value is computed by the exact floating-point
/// expression of the dense draw, the normalization scale replays the
/// dense `norm2` accumulator pattern over the stored rows
/// ([`linalg::sparse_norm2`]), and entries the dense path would hold
/// as exact zeros (outside the window, pulse tails that underflow
/// `exp`, values flushed to zero by the normalization divide) are
/// dropped — so the result equals `CscMat::from_dense` of the dense
/// draw, bit for bit.
fn draw_toeplitz_csc(
    m: usize,
    n: usize,
    pulse_width: f64,
    pulse_cutoff: f64,
) -> CscMat {
    let w2 = 2.0 * pulse_width * pulse_width;
    let lim = toeplitz_limit(pulse_width, pulse_cutoff);
    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut row_idx: Vec<u32> = Vec::new();
    let mut val: Vec<f64> = Vec::new();
    col_ptr.push(0);
    let mut rows_j: Vec<u32> = Vec::new();
    let mut vals_j: Vec<f64> = Vec::new();
    for j in 0..n {
        let centre = (j as f64) * (m as f64) / (n as f64);
        rows_j.clear();
        vals_j.clear();
        // Candidate row segments covering the cyclic pulse window,
        // padded by one row per side so boundary rounding in the
        // segment arithmetic can never exclude a row the exact
        // per-row test below keeps.  Segments are ascending and
        // disjoint (the padded arc is shorter than m in the else
        // branch), so the CSC rows come out sorted; every candidate
        // still goes through the same `d ≤ lim` predicate as the
        // dense draw, keeping the two bit-identical.
        let segments: [(usize, usize); 2] =
            if !lim.is_finite() || 2.0 * lim + 6.0 >= m as f64 {
                [(0, m), (0, 0)]
            } else {
                let lo = (centre - lim).floor() as i64 - 1;
                let hi = (centre + lim).ceil() as i64 + 1;
                let a = lo.rem_euclid(m as i64) as usize;
                let b = hi.rem_euclid(m as i64) as usize;
                if a <= b {
                    [(a, b + 1), (0, 0)]
                } else {
                    [(0, b + 1), (a, m)]
                }
            };
        for (s, e) in segments {
            for i in s..e {
                let mut d = (i as f64 - centre).abs();
                d = d.min(m as f64 - d);
                if d <= lim {
                    let v = (-d * d / w2).exp();
                    if v != 0.0 {
                        rows_j.push(i as u32);
                        vals_j.push(v);
                    }
                }
            }
        }
        // Bitwise the dense normalize_columns: the zeros outside the
        // window are no-ops in the norm accumulation, and the same
        // near-zero guard applies.
        let nrm = linalg::sparse_norm2(&rows_j, &vals_j, m);
        if nrm > 1e-300 {
            for v in vals_j.iter_mut() {
                *v /= nrm;
            }
        }
        for (&i, &v) in rows_j.iter().zip(&vals_j) {
            // A normalized tail value can flush to zero; the dense
            // store would then hold an exact 0.0 the converter drops.
            if v != 0.0 {
                row_idx.push(i);
                val.push(v);
            }
        }
        col_ptr.push(row_idx.len());
    }
    CscMat::from_parts(m, n, col_ptr, row_idx, val)
}

/// Draw `y` uniformly on the unit sphere.
pub fn draw_observation(m: usize, rng: &mut Pcg64) -> Vec<f64> {
    rng.unit_sphere(m)
}

/// Generate a full instance.  λ is `lam_ratio · λ_max(A, y)`, recomputed
/// per draw as in the paper.  The dictionary is drawn in
/// `config.format`; dense and CSC draws of one config yield bitwise
/// identical problems (same RNG stream, same matrix, replayed
/// precomputations).
pub fn generate(config: &InstanceConfig, seed: u64) -> Instance {
    assert!(config.lam_ratio > 0.0 && config.lam_ratio < 1.0,
            "lam_ratio must be in (0, 1) for a non-trivial instance");
    let mut rng = Pcg64::new(seed);
    let store = draw_dictionary_store(
        config.kind, config.m, config.n, config.pulse_width,
        config.pulse_cutoff, config.format, &mut rng,
    );
    let y = draw_observation(config.m, &mut rng);
    // Probe λ_max via a throwaway problem at λ = 1.
    let probe = LassoProblem::from_store(store, y, 1.0);
    let lam = config.lam_ratio * probe.lam_max();
    let problem = probe.with_lambda(lam);
    Instance { problem, config: config.clone(), seed }
}

/// PCG stream selector for batch observations: observation `b` of a
/// batch draw comes from `Pcg64::with_stream(seed, BATCH_RHS_STREAM ^ b)`
/// — its own independent stream, distinct from the default stream the
/// dictionary (and [`generate`]) consumes.
const BATCH_RHS_STREAM: u64 = 0xba7c_0b5e_7fab_1e55;

/// Draw **one** dictionary and `batch` observations over it — the
/// multi-RHS serving workload ([`crate::solver::solve_many`]).
///
/// The dictionary is drawn exactly as [`generate`] draws it (same
/// leading RNG stream for `seed`, same storage format rules), then
/// wrapped in a [`SharedDict`] so its column norms, nonzero counts and
/// spectral norm are computed once for the whole batch.  Observation
/// `b` is drawn uniformly on the unit sphere from its own PCG stream
/// keyed by `(seed, b)`, which makes batches **prefix-stable**:
/// extending a batch from B to B+1 right-hand sides never changes the
/// first B.
///
/// λ is deliberately *not* resolved here — pair each observation with
/// a [`crate::problem::LambdaSpec`] (usually
/// `RatioOfMax(config.lam_ratio)`, the paper's per-observation
/// protocol) when building [`crate::solver::BatchRhs`] requests.
pub fn generate_batch(
    config: &InstanceConfig,
    seed: u64,
    batch: usize,
) -> (SharedDict, Vec<Vec<f64>>) {
    let mut rng = Pcg64::new(seed);
    let store = draw_dictionary_store(
        config.kind, config.m, config.n, config.pulse_width,
        config.pulse_cutoff, config.format, &mut rng,
    );
    let shared = SharedDict::new(store);
    let ys = (0..batch)
        .map(|b| {
            let mut r =
                Pcg64::with_stream(seed, BATCH_RHS_STREAM ^ b as u64);
            draw_observation(config.m, &mut r)
        })
        .collect();
    (shared, ys)
}

/// A planted sparse-recovery instance: `y = A x₀ + σ·noise` with `k`
/// spikes.  Not in the paper's evaluation, but the natural workload for
/// the deconvolution example.
pub fn generate_planted(
    config: &InstanceConfig,
    k: usize,
    noise_sigma: f64,
    seed: u64,
) -> (Instance, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let store = draw_dictionary_store(
        config.kind, config.m, config.n, config.pulse_width,
        config.pulse_cutoff, config.format, &mut rng,
    );
    let mut x0 = vec![0.0; config.n];
    for idx in rng.sample_indices(config.n, k) {
        // Amplitudes bounded away from zero so the support is meaningful.
        x0[idx] = (1.0 + rng.uniform()) * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    let mut y = vec![0.0; config.m];
    store.gemv(&x0, &mut y);
    for v in y.iter_mut() {
        *v += noise_sigma * rng.normal();
    }
    let probe = LassoProblem::from_store(store, y, 1.0);
    let lam = config.lam_ratio * probe.lam_max();
    let problem = probe.with_lambda(lam);
    (Instance { problem, config: config.clone(), seed }, x0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self};

    #[test]
    fn gaussian_instance_matches_paper_setup() {
        let cfg = InstanceConfig::paper(DictKind::Gaussian, 0.5);
        let inst = generate(&cfg, 0);
        let p = &inst.problem;
        assert_eq!(p.m(), 100);
        assert_eq!(p.n(), 500);
        // Columns normalized.
        for j in 0..p.n() {
            assert!((linalg::norm2(p.a().col(j)) - 1.0).abs() < 1e-12);
        }
        // y on unit sphere.
        assert!((linalg::norm2(p.y()) - 1.0).abs() < 1e-12);
        // λ at the requested ratio.
        assert!((p.lam() / p.lam_max() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn toeplitz_columns_are_shifted_pulses() {
        let cfg = InstanceConfig {
            m: 64,
            n: 128,
            kind: DictKind::Toeplitz,
            lam_ratio: 0.5,
            pulse_width: 3.0,
            ..Default::default()
        };
        let inst = generate(&cfg, 1);
        let a = inst.problem.a();
        // Each column peaks at its pulse centre.
        for j in [0usize, 32, 64, 127] {
            let col = a.col(j);
            let (imax, _) = crate::linalg::argmax_abs(col);
            let centre = (j as f64 * 64.0 / 128.0).round() as i64;
            let d = (imax as i64 - centre).rem_euclid(64).min(
                (centre - imax as i64).rem_euclid(64),
            );
            assert!(d <= 1, "col {j}: peak {imax} vs centre {centre}");
        }
        // Adjacent atoms strongly correlated (the screening-hard case).
        let c = linalg::dot(a.col(10), a.col(11));
        assert!(c > 0.8, "adjacent correlation {c}");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let cfg = InstanceConfig::paper(DictKind::Gaussian, 0.3);
        let a = generate(&cfg, 7);
        let b = generate(&cfg, 7);
        let c = generate(&cfg, 8);
        assert_eq!(a.problem.a().as_slice(), b.problem.a().as_slice());
        assert_ne!(a.problem.a().as_slice(), c.problem.a().as_slice());
    }

    #[test]
    fn planted_instance_recovers_shape() {
        let cfg = InstanceConfig {
            m: 50,
            n: 100,
            kind: DictKind::Toeplitz,
            lam_ratio: 0.3,
            pulse_width: 2.0,
            ..Default::default()
        };
        let (inst, x0) = generate_planted(&cfg, 5, 0.01, 3);
        assert_eq!(x0.len(), 100);
        assert_eq!(linalg::support_size(&x0, 0.0), 5);
        // y should correlate with the planted support atoms.
        let p = &inst.problem;
        let support: Vec<usize> =
            (0..100).filter(|&j| x0[j] != 0.0).collect();
        let max_on = support
            .iter()
            .map(|&j| p.aty()[j].abs())
            .fold(0.0f64, f64::max);
        assert!(max_on > 0.5, "planted atoms barely correlated: {max_on}");
    }

    /// The CSC draw of a config must be the dense draw's matrix,
    /// bit for bit — direct Toeplitz build and Gaussian converter
    /// alike — and the generated problems must share every cache.
    #[test]
    fn csc_draw_is_bitwise_the_dense_matrix() {
        for (kind, cutoff) in [
            (DictKind::Toeplitz, 4.0),
            (DictKind::Toeplitz, 0.0),
            (DictKind::Gaussian, 0.0),
        ] {
            let mk = |format| InstanceConfig {
                m: 57,
                n: 140,
                kind,
                lam_ratio: 0.5,
                pulse_width: 3.0,
                pulse_cutoff: cutoff,
                format,
            };
            let d = generate(&mk(DictFormat::Dense), 11);
            let c = generate(&mk(DictFormat::Csc), 11);
            let csc = c.problem.store().as_csc().unwrap();
            assert_eq!(
                csc.to_dense().as_slice(),
                d.problem.a().as_slice(),
                "{kind:?} cutoff {cutoff}: matrices differ"
            );
            assert_eq!(d.problem.col_nnz(), c.problem.col_nnz());
            assert_eq!(
                d.problem.lam().to_bits(),
                c.problem.lam().to_bits()
            );
            assert_eq!(
                d.problem.lipschitz().to_bits(),
                c.problem.lipschitz().to_bits()
            );
            for (a, b) in d.problem.aty().iter().zip(c.problem.aty()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in
                d.problem.col_norms().iter().zip(c.problem.col_norms())
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// A positive cutoff plants genuine zeros, and the CSC store's nnz
    /// shrinks accordingly (the sparse-deconvolution win).
    #[test]
    fn pulse_cutoff_truncates_to_exact_zeros() {
        let cfg = InstanceConfig {
            m: 200,
            n: 300,
            kind: DictKind::Toeplitz,
            lam_ratio: 0.5,
            pulse_width: 4.0,
            pulse_cutoff: 5.0,
            format: DictFormat::Csc,
        };
        let inst = generate(&cfg, 3);
        let store = inst.problem.store();
        let nnz = store.nnz();
        let dense_len = cfg.m * cfg.n;
        assert!(nnz < dense_len / 4, "nnz {nnz} of {dense_len}");
        // Window radius 5σ = 20 rows ⇒ ≤ 41 rows per column.
        for j in 0..cfg.n {
            let c = inst.problem.col_nnz()[j];
            assert!(c <= 41, "col {j}: {c} nnz");
            assert!(c >= 1, "col {j} empty");
        }
        // Columns still unit-norm.
        for n in inst.problem.col_norms() {
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    /// The batch draw shares [`generate`]'s dictionary bit for bit,
    /// its observations sit on the unit sphere, and batches are
    /// prefix-stable (growing B never rewrites earlier RHS).
    #[test]
    fn batch_draw_shares_generates_dictionary_and_is_prefix_stable() {
        let cfg = InstanceConfig::paper(DictKind::Gaussian, 0.5);
        let inst = generate(&cfg, 3);
        let (shared, ys) = generate_batch(&cfg, 3, 4);
        assert_eq!(
            shared.store().as_dense().unwrap().as_slice(),
            inst.problem.a().as_slice(),
            "batch dictionary differs from the per-instance draw"
        );
        for (s, d) in shared.col_norms().iter().zip(inst.problem.col_norms())
        {
            assert_eq!(s.to_bits(), d.to_bits());
        }
        assert_eq!(
            shared.lipschitz().to_bits(),
            inst.problem.lipschitz().to_bits()
        );
        for y in &ys {
            assert!((linalg::norm2(y) - 1.0).abs() < 1e-12);
        }
        // Distinct observations, prefix-stable extension.
        assert_ne!(ys[0], ys[1]);
        let (_, longer) = generate_batch(&cfg, 3, 6);
        for (a, b) in ys.iter().zip(&longer) {
            assert_eq!(a, b, "extending the batch rewrote an earlier RHS");
        }
    }

    #[test]
    fn parse_kind() {
        assert_eq!(DictKind::parse("gaussian"), Some(DictKind::Gaussian));
        assert_eq!(DictKind::parse("Toeplitz"), Some(DictKind::Toeplitz));
        assert_eq!(DictKind::parse("conv"), Some(DictKind::Toeplitz));
        assert_eq!(DictKind::parse("bogus"), None);
    }

    #[test]
    #[should_panic]
    fn lam_ratio_out_of_range_panics() {
        let cfg = InstanceConfig {
            m: 10, n: 20, kind: DictKind::Gaussian,
            lam_ratio: 1.5, pulse_width: 2.0,
            ..Default::default()
        };
        generate(&cfg, 0);
    }
}
