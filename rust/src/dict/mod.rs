//! Dictionary / instance generators reproducing the paper's §V setup.
//!
//! * `y` drawn uniformly on the unit sphere `S^{m-1}`;
//! * `A` either (i) i.i.d. `N(0,1)` entries, or (ii) a Toeplitz
//!   structure whose columns are shifted samples of a Gaussian curve
//!   (a convolutional dictionary — the sparse-deconvolution workload);
//! * columns normalized to `‖a_i‖₂ = 1`;
//! * `λ = ratio · λ_max` with `ratio ∈ {0.3, 0.5, 0.8}` in the paper.

use crate::linalg::Mat;
use crate::problem::LassoProblem;
use crate::util::rng::Pcg64;

/// Which dictionary family to draw (paper §V).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DictKind {
    /// i.i.d. standard-normal entries, normalized columns.
    Gaussian,
    /// Toeplitz: column `j` is a Gaussian pulse centred at row
    /// `j·m/n` (cyclically shifted), normalized.  Adjacent atoms are
    /// highly correlated — the hard case for screening.
    Toeplitz,
}

impl DictKind {
    pub fn parse(s: &str) -> Option<DictKind> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" | "iid" | "normal" => Some(DictKind::Gaussian),
            "toeplitz" | "conv" | "convolutional" => Some(DictKind::Toeplitz),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DictKind::Gaussian => "gaussian",
            DictKind::Toeplitz => "toeplitz",
        }
    }
}

/// Instance-generation configuration.
#[derive(Clone, Debug)]
pub struct InstanceConfig {
    pub m: usize,
    pub n: usize,
    pub kind: DictKind,
    /// λ as a fraction of λ_max (paper: 0.3 / 0.5 / 0.8).
    pub lam_ratio: f64,
    /// Width (std dev, in rows) of the Toeplitz Gaussian pulse.
    pub pulse_width: f64,
}

impl InstanceConfig {
    /// The paper's base setup: (m, n) = (100, 500).
    pub fn paper(kind: DictKind, lam_ratio: f64) -> Self {
        InstanceConfig { m: 100, n: 500, kind, lam_ratio, pulse_width: 4.0 }
    }
}

/// A generated instance: problem + provenance.
#[derive(Clone, Debug)]
pub struct Instance {
    pub problem: LassoProblem,
    pub config: InstanceConfig,
    pub seed: u64,
}

/// Draw the dictionary matrix only (unnormalized-then-normalized).
pub fn draw_dictionary(
    kind: DictKind,
    m: usize,
    n: usize,
    pulse_width: f64,
    rng: &mut Pcg64,
) -> Mat {
    let mut a = match kind {
        DictKind::Gaussian => {
            let mut mat = Mat::zeros(m, n);
            for j in 0..n {
                for v in mat.col_mut(j) {
                    *v = rng.normal();
                }
            }
            mat
        }
        DictKind::Toeplitz => {
            let mut mat = Mat::zeros(m, n);
            let w2 = 2.0 * pulse_width * pulse_width;
            for j in 0..n {
                // Pulse centre moves linearly through the rows so the
                // atoms tile the observation window (cyclic wrap).
                let centre = (j as f64) * (m as f64) / (n as f64);
                let col = mat.col_mut(j);
                for (i, v) in col.iter_mut().enumerate() {
                    // cyclic distance
                    let mut d = (i as f64 - centre).abs();
                    d = d.min(m as f64 - d);
                    *v = (-d * d / w2).exp();
                }
            }
            mat
        }
    };
    a.normalize_columns();
    a
}

/// Draw `y` uniformly on the unit sphere.
pub fn draw_observation(m: usize, rng: &mut Pcg64) -> Vec<f64> {
    rng.unit_sphere(m)
}

/// Generate a full instance.  λ is `lam_ratio · λ_max(A, y)`, recomputed
/// per draw as in the paper.
pub fn generate(config: &InstanceConfig, seed: u64) -> Instance {
    assert!(config.lam_ratio > 0.0 && config.lam_ratio < 1.0,
            "lam_ratio must be in (0, 1) for a non-trivial instance");
    let mut rng = Pcg64::new(seed);
    let a = draw_dictionary(config.kind, config.m, config.n,
                            config.pulse_width, &mut rng);
    let y = draw_observation(config.m, &mut rng);
    // Probe λ_max via a throwaway problem at λ = 1.
    let probe = LassoProblem::new(a, y, 1.0);
    let lam = config.lam_ratio * probe.lam_max();
    let problem = probe.with_lambda(lam);
    Instance { problem, config: config.clone(), seed }
}

/// A planted sparse-recovery instance: `y = A x₀ + σ·noise` with `k`
/// spikes.  Not in the paper's evaluation, but the natural workload for
/// the deconvolution example.
pub fn generate_planted(
    config: &InstanceConfig,
    k: usize,
    noise_sigma: f64,
    seed: u64,
) -> (Instance, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    let a = draw_dictionary(config.kind, config.m, config.n,
                            config.pulse_width, &mut rng);
    let mut x0 = vec![0.0; config.n];
    for idx in rng.sample_indices(config.n, k) {
        // Amplitudes bounded away from zero so the support is meaningful.
        x0[idx] = (1.0 + rng.uniform()) * if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    let mut y = vec![0.0; config.m];
    crate::linalg::gemv(&a, &x0, &mut y);
    for v in y.iter_mut() {
        *v += noise_sigma * rng.normal();
    }
    let probe = LassoProblem::new(a, y, 1.0);
    let lam = config.lam_ratio * probe.lam_max();
    let problem = probe.with_lambda(lam);
    (Instance { problem, config: config.clone(), seed }, x0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self};

    #[test]
    fn gaussian_instance_matches_paper_setup() {
        let cfg = InstanceConfig::paper(DictKind::Gaussian, 0.5);
        let inst = generate(&cfg, 0);
        let p = &inst.problem;
        assert_eq!(p.m(), 100);
        assert_eq!(p.n(), 500);
        // Columns normalized.
        for j in 0..p.n() {
            assert!((linalg::norm2(p.a().col(j)) - 1.0).abs() < 1e-12);
        }
        // y on unit sphere.
        assert!((linalg::norm2(p.y()) - 1.0).abs() < 1e-12);
        // λ at the requested ratio.
        assert!((p.lam() / p.lam_max() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn toeplitz_columns_are_shifted_pulses() {
        let cfg = InstanceConfig {
            m: 64,
            n: 128,
            kind: DictKind::Toeplitz,
            lam_ratio: 0.5,
            pulse_width: 3.0,
        };
        let inst = generate(&cfg, 1);
        let a = inst.problem.a();
        // Each column peaks at its pulse centre.
        for j in [0usize, 32, 64, 127] {
            let col = a.col(j);
            let (imax, _) = crate::linalg::argmax_abs(col);
            let centre = (j as f64 * 64.0 / 128.0).round() as i64;
            let d = (imax as i64 - centre).rem_euclid(64).min(
                (centre - imax as i64).rem_euclid(64),
            );
            assert!(d <= 1, "col {j}: peak {imax} vs centre {centre}");
        }
        // Adjacent atoms strongly correlated (the screening-hard case).
        let c = linalg::dot(a.col(10), a.col(11));
        assert!(c > 0.8, "adjacent correlation {c}");
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let cfg = InstanceConfig::paper(DictKind::Gaussian, 0.3);
        let a = generate(&cfg, 7);
        let b = generate(&cfg, 7);
        let c = generate(&cfg, 8);
        assert_eq!(a.problem.a().as_slice(), b.problem.a().as_slice());
        assert_ne!(a.problem.a().as_slice(), c.problem.a().as_slice());
    }

    #[test]
    fn planted_instance_recovers_shape() {
        let cfg = InstanceConfig {
            m: 50,
            n: 100,
            kind: DictKind::Toeplitz,
            lam_ratio: 0.3,
            pulse_width: 2.0,
        };
        let (inst, x0) = generate_planted(&cfg, 5, 0.01, 3);
        assert_eq!(x0.len(), 100);
        assert_eq!(linalg::support_size(&x0, 0.0), 5);
        // y should correlate with the planted support atoms.
        let p = &inst.problem;
        let support: Vec<usize> =
            (0..100).filter(|&j| x0[j] != 0.0).collect();
        let max_on = support
            .iter()
            .map(|&j| p.aty()[j].abs())
            .fold(0.0f64, f64::max);
        assert!(max_on > 0.5, "planted atoms barely correlated: {max_on}");
    }

    #[test]
    fn parse_kind() {
        assert_eq!(DictKind::parse("gaussian"), Some(DictKind::Gaussian));
        assert_eq!(DictKind::parse("Toeplitz"), Some(DictKind::Toeplitz));
        assert_eq!(DictKind::parse("conv"), Some(DictKind::Toeplitz));
        assert_eq!(DictKind::parse("bogus"), None);
    }

    #[test]
    #[should_panic]
    fn lam_ratio_out_of_range_panics() {
        let cfg = InstanceConfig {
            m: 10, n: 20, kind: DictKind::Gaussian,
            lam_ratio: 1.5, pulse_width: 2.0,
        };
        generate(&cfg, 0);
    }
}
