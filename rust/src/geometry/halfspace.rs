//! Half-spaces `H(g, δ) = {u : ⟨g, u⟩ ≤ δ}` (eq. 13) — the "dual cutting
//! half-spaces" of the paper when `(g, δ) ∈ G` (Lemma 1).

use crate::linalg::{self};

/// A half-space `{u : ⟨g,u⟩ ≤ δ}`.
///
/// Degenerate case `g = 0` (paper footnote 1): the half-space is all of
/// `R^m` when `δ ≥ 0` and empty when `δ < 0`.
#[derive(Clone, Debug)]
pub struct HalfSpace {
    pub g: Vec<f64>,
    pub delta: f64,
}

impl HalfSpace {
    pub fn new(g: Vec<f64>, delta: f64) -> Self {
        HalfSpace { g, delta }
    }

    /// ‖g‖₂.
    pub fn g_norm(&self) -> f64 {
        linalg::norm2(&self.g)
    }

    /// Is the normal (numerically) zero?
    pub fn is_degenerate(&self) -> bool {
        self.g_norm() < super::EPS
    }

    /// Membership.
    pub fn contains(&self, u: &[f64], tol: f64) -> bool {
        if self.is_degenerate() {
            return self.delta >= -tol;
        }
        linalg::dot(&self.g, u) <= self.delta + tol
    }

    /// Signed distance from `point` to the boundary hyperplane, positive
    /// when the point is strictly inside (`⟨g,p⟩ < δ`).
    ///
    /// Returns `+inf` for a degenerate half-space covering `R^m`.
    pub fn signed_distance(&self, point: &[f64]) -> f64 {
        let gn = self.g_norm();
        if gn < super::EPS {
            return if self.delta >= 0.0 { f64::INFINITY } else { f64::NEG_INFINITY };
        }
        (self.delta - linalg::dot(&self.g, point)) / gn
    }

    /// The Hölder cut of Theorem 1: `H(Ax, λ‖x‖₁)` — safe for *any*
    /// primal point `x` by Lemma 1 / Hölder's inequality.
    pub fn holder_cut(
        a: &crate::linalg::Mat,
        x: &[f64],
        lam: f64,
    ) -> HalfSpace {
        let mut g = vec![0.0; a.rows()];
        crate::linalg::gemv(a, x, &mut g);
        HalfSpace { g, delta: lam * linalg::norm1(x) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Runner;

    #[test]
    fn membership_and_distance() {
        let h = HalfSpace::new(vec![1.0, 0.0], 2.0);
        assert!(h.contains(&[1.0, 5.0], 0.0));
        assert!(h.contains(&[2.0, 0.0], 0.0));
        assert!(!h.contains(&[2.1, 0.0], 0.0));
        assert!((h.signed_distance(&[0.0, 0.0]) - 2.0).abs() < 1e-15);
        assert!((h.signed_distance(&[3.0, 0.0]) + 1.0).abs() < 1e-15);
    }

    #[test]
    fn degenerate_cases() {
        let all = HalfSpace::new(vec![0.0, 0.0], 0.5);
        assert!(all.is_degenerate());
        assert!(all.contains(&[100.0, -100.0], 0.0));
        assert_eq!(all.signed_distance(&[1.0, 1.0]), f64::INFINITY);
        let empty = HalfSpace::new(vec![0.0, 0.0], -0.5);
        assert!(!empty.contains(&[0.0, 0.0], 0.0));
    }

    #[test]
    fn holder_cut_is_safe_for_dual_points() {
        // Lemma 1: any dual-feasible u satisfies <Ax, u> <= lam ||x||_1.
        Runner::new(55).cases(40).run("holder cut safety", |g| {
            let m = g.usize_in(3, 20);
            let n = g.usize_in(2, 40);
            let a = g.dictionary(m, n);
            let y = g.observation(m);
            let mut aty = vec![0.0; n];
            crate::linalg::gemv_t(&a, &y, &mut aty);
            let lam_max = crate::linalg::norm_inf(&aty);
            if lam_max < 1e-9 {
                return Ok(());
            }
            let lam = g.f64_in(0.2, 0.9) * lam_max;
            let p = crate::problem::LassoProblem::new(a, y, lam);
            // u: dual-scaled residual at a random sparse x' (feasible by
            // construction).
            let xp = g.vec_sparse(n, 4);
            let ev = p.eval(&xp);
            // Cut built from a DIFFERENT x — must still contain u.
            let x = g.vec_sparse(n, 6);
            let h = HalfSpace::holder_cut(p.a(), &x, lam);
            if !h.contains(&ev.u, 1e-9) {
                return Err("dual point escaped the Hölder cut".into());
            }
            Ok(())
        });
    }
}
