//! Closed balls `B(c, R)` (eq. 10) and the sphere screening test (eq. 11).

use crate::linalg::{self};

/// A closed ball `B(c, R)`.
#[derive(Clone, Debug)]
pub struct Ball {
    pub center: Vec<f64>,
    pub radius: f64,
}

impl Ball {
    pub fn new(center: Vec<f64>, radius: f64) -> Self {
        assert!(radius >= 0.0, "radius must be nonnegative");
        Ball { center, radius }
    }

    /// Membership test (with tolerance for fp noise).
    pub fn contains(&self, u: &[f64], tol: f64) -> bool {
        self.dist_from_center(u) <= self.radius + tol
    }

    fn dist_from_center(&self, u: &[f64]) -> f64 {
        debug_assert_eq!(u.len(), self.center.len());
        let mut d = 0.0;
        for (a, b) in u.iter().zip(&self.center) {
            d += (a - b) * (a - b);
        }
        d.sqrt()
    }

    /// `max_{u∈B} ⟨a, u⟩ = ⟨a,c⟩ + R‖a‖` (one-sided).
    pub fn max_inner(&self, a: &[f64]) -> f64 {
        linalg::dot(a, &self.center) + self.radius * linalg::norm2(a)
    }

    /// `max_{u∈B} |⟨a, u⟩| = |⟨a,c⟩| + R‖a‖` (eq. 11).
    pub fn max_abs_inner(&self, a: &[f64]) -> f64 {
        linalg::dot(a, &self.center).abs()
            + self.radius * linalg::norm2(a)
    }

    /// Same from precomputed statistics (hot path): `atc = ⟨a,c⟩`,
    /// `anrm = ‖a‖`.
    #[inline]
    pub fn max_abs_inner_stat(&self, atc: f64, anrm: f64) -> f64 {
        atc.abs() + self.radius * anrm
    }

    /// `Rad(B) = R` (eq. 32 for a ball).
    pub fn rad(&self) -> f64 {
        self.radius
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{Gen, Runner};

    #[test]
    fn max_abs_inner_matches_definition() {
        let b = Ball::new(vec![1.0, 0.0], 2.0);
        // a = (0,1): |<a,c>| = 0, + 2*1 = 2
        assert!((b.max_abs_inner(&[0.0, 1.0]) - 2.0).abs() < 1e-15);
        // a = (1,0): 1 + 2 = 3
        assert!((b.max_abs_inner(&[1.0, 0.0]) - 3.0).abs() < 1e-15);
    }

    #[test]
    fn max_inner_dominates_samples() {
        Runner::new(77).cases(50).run("ball max_inner is an upper bound", |g| {
            let m = g.usize_in(2, 12);
            let c = g.vec_normal(m);
            let radius = g.f64_in(0.0, 2.0);
            let b = Ball::new(c.clone(), radius);
            let a = g.vec_normal(m);
            let bound = b.max_inner(&a);
            for _ in 0..100 {
                let mut u = g.rng().unit_ball(m);
                for (ui, ci) in u.iter_mut().zip(&c) {
                    *ui = ci + radius * *ui;
                }
                if crate::linalg::dot(&a, &u) > bound + 1e-9 {
                    return Err("sample exceeded closed form".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn max_inner_is_attained() {
        // maximizer u* = c + R a/||a||
        let mut g = Gen::for_case(5, 0);
        let c = g.vec_normal(6);
        let a = g.vec_normal(6);
        let b = Ball::new(c.clone(), 1.5);
        let na = crate::linalg::norm2(&a);
        let u_star: Vec<f64> =
            c.iter().zip(&a).map(|(ci, ai)| ci + 1.5 * ai / na).collect();
        let val = crate::linalg::dot(&a, &u_star);
        assert!((val - b.max_inner(&a)).abs() < 1e-10);
        assert!(b.contains(&u_star, 1e-12));
    }

    #[test]
    fn stat_variant_matches() {
        let mut g = Gen::for_case(6, 0);
        let c = g.vec_normal(8);
        let a = g.vec_normal(8);
        let b = Ball::new(c.clone(), 0.7);
        let atc = crate::linalg::dot(&a, &c);
        let anrm = crate::linalg::norm2(&a);
        assert!(
            (b.max_abs_inner(&a) - b.max_abs_inner_stat(atc, anrm)).abs()
                < 1e-12
        );
    }

    #[test]
    #[should_panic]
    fn negative_radius_panics() {
        Ball::new(vec![0.0], -1.0);
    }
}
