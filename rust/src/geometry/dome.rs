//! Domes `D(c, R, g, δ) = B(c,R) ∩ H(g,δ)` (eq. 12) with the closed-form
//! maximum of eq. (14)-(15) and the `Rad(·)` of eq. (32).

use super::{Ball, HalfSpace, EPS};
use crate::linalg::{self};

/// Relative inflation applied to the cap-rim branch of
/// [`Dome::sup_norm`].  The rim expression is ~10 floating-point
/// operations, so its relative rounding error is a few ulps (≲ 5e-15);
/// inflating by 1e-13 makes the returned value provably dominate the
/// exact supremum while costing a vanishing amount of group-test
/// power.  The two ball-bound branches are exact upper bounds already
/// and are **not** inflated, so a dome whose cut is inactive returns
/// the enclosing ball's `‖c‖ + R` bit for bit.
pub const SUP_NORM_FP_MARGIN: f64 = 1e-13;

/// A dome: ball ∩ half-space.
#[derive(Clone, Debug)]
pub struct Dome {
    pub ball: Ball,
    pub half: HalfSpace,
    /// Cached `ψ₂ = min((δ − ⟨g,c⟩)/(R‖g‖), 1)`, clamped to [−1, 1];
    /// `1.0` when the cut is degenerate (no effective half-space).
    psi2: f64,
    /// Cached `‖g‖` — the per-atom test is O(1) only because this is
    /// NOT recomputed per atom (perf log entry 1 in EXPERIMENTS.md).
    g_norm: f64,
    /// Cached `√(1−ψ₂²)` — constant across atoms, hoisted out of
    /// `f(·, ψ₂)` (perf log entry 2).
    sin2: f64,
}

impl Dome {
    pub fn new(ball: Ball, half: HalfSpace) -> Self {
        let psi2 = Self::compute_psi2(&ball, &half);
        let g_norm = half.g_norm();
        let sin2 = (1.0 - psi2 * psi2).max(0.0).sqrt();
        Dome { ball, half, psi2, g_norm, sin2 }
    }

    /// `f(ψ₁, ψ₂)` with the ψ₂ trigonometry precomputed.
    #[inline(always)]
    fn f_cached(&self, psi1: f64) -> f64 {
        if psi1 <= self.psi2 {
            1.0
        } else {
            let s1 = (1.0 - psi1 * psi1).max(0.0).sqrt();
            psi1 * self.psi2 + s1 * self.sin2
        }
    }

    /// ψ₂ per eq. (15).  Degenerate cases (`g = 0` or `R = 0`) give
    /// ψ₂ = 1, turning the dome test into the sphere test.
    fn compute_psi2(ball: &Ball, half: &HalfSpace) -> f64 {
        let gn = half.g_norm();
        if gn < EPS || ball.radius < EPS {
            return 1.0;
        }
        let margin = half.delta - linalg::dot(&half.g, &ball.center);
        (margin / (ball.radius * gn)).clamp(-1.0, 1.0)
    }

    /// Cached ψ₂.
    pub fn psi2(&self) -> f64 {
        self.psi2
    }

    /// The signed cut distance `d = (δ − ⟨g,c⟩)/‖g‖` (= ψ₂·R when the
    /// raw value is within [−R, R]).
    pub fn cut_distance(&self) -> f64 {
        self.half.signed_distance(&self.ball.center)
    }

    /// Is the dome (numerically) empty?  `ψ₂ ≤ −1` means the half-space
    /// excludes the whole ball.
    pub fn is_empty(&self) -> bool {
        if self.half.is_degenerate() {
            return self.half.delta < 0.0;
        }
        self.cut_distance() <= -self.ball.radius
    }

    /// Membership.
    pub fn contains(&self, u: &[f64], tol: f64) -> bool {
        self.ball.contains(u, tol) && self.half.contains(u, tol)
    }

    /// `max_{u∈D} ⟨a, u⟩` (eq. 15): `⟨a,c⟩ + R‖a‖·f(ψ₁, ψ₂)`.
    pub fn max_inner(&self, a: &[f64]) -> f64 {
        let atc = linalg::dot(a, &self.ball.center);
        let anrm = linalg::norm2(a);
        let atg = linalg::dot(a, &self.half.g);
        self.max_inner_stat(atc, atg, anrm)
    }

    /// `max_{u∈D} |⟨a, u⟩|` (eq. 14).
    pub fn max_abs_inner(&self, a: &[f64]) -> f64 {
        let atc = linalg::dot(a, &self.ball.center);
        let anrm = linalg::norm2(a);
        let atg = linalg::dot(a, &self.half.g);
        self.max_abs_inner_stat(atc, atg, anrm)
    }

    /// eq. (15) from precomputed statistics (hot path).
    #[inline]
    pub fn max_inner_stat(&self, atc: f64, atg: f64, anrm: f64) -> f64 {
        let gn = self.g_norm;
        let psi1 = if anrm * gn < EPS {
            0.0
        } else {
            (atg / (anrm * gn)).clamp(-1.0, 1.0)
        };
        atc + self.ball.radius * anrm * self.f_cached(psi1)
    }

    /// eq. (14) from precomputed statistics (hot path).
    #[inline]
    pub fn max_abs_inner_stat(&self, atc: f64, atg: f64, anrm: f64) -> f64 {
        let gn = self.g_norm;
        let psi1 = if anrm * gn < EPS {
            0.0
        } else {
            (atg / (anrm * gn)).clamp(-1.0, 1.0)
        };
        let r_an = self.ball.radius * anrm;
        let up = atc + r_an * self.f_cached(psi1);
        let dn = -atc + r_an * self.f_cached(-psi1);
        up.max(dn)
    }

    /// Closed-form `sup_{u∈D} ‖u‖` — the dual-norm factor of the joint
    /// screening test, with the half-space cut **intersected** instead
    /// of ignored.
    ///
    /// `‖u‖` is convex, so its maximum over `B(c,R) ∩ {⟨g,u⟩ ≤ δ}` is
    /// attained on the boundary.  Two cases, with `d = (δ−⟨g,c⟩)/‖g‖`
    /// the signed cut distance and `c_g = ⟨g,c⟩/‖g‖` the center's
    /// coordinate along `ĝ = g/‖g‖`:
    ///
    /// * the ball's farthest-from-origin point `c·(1 + R/‖c‖)`
    ///   satisfies the cut (`R·c_g ≤ d·‖c‖`, or the cut misses the
    ///   ball entirely, `d ≥ R`) — the dome attains the ball supremum
    ///   `‖c‖ + R`;
    /// * otherwise the maximizer sits on the **cap rim**
    ///   `{‖u−c‖ = R, ⟨g,u⟩ = δ}`: writing `u = c + d·ĝ + ρ·w` with
    ///   `ρ = √(R²−d²)` and `w ⊥ ĝ` unit, `‖u‖²` is maximized by
    ///   pointing `w` along the component of `c` orthogonal to `ĝ`
    ///   (`c_⊥ = √(‖c‖²−c_g²)`), giving
    ///
    ///   ```text
    ///     sup ‖u‖ = √( (c_g + d)² + (c_⊥ + ρ)² )
    ///   ```
    ///
    ///   — exact, O(m), from quantities already cached at build time.
    ///
    /// The rim value is inflated by [`SUP_NORM_FP_MARGIN`] (so floating
    /// point cannot round it below the true supremum) and clamped to
    /// the ball bound (the rim point lies in the ball, so the exact rim
    /// value never exceeds `‖c‖ + R`); degenerate cuts and `R ≈ 0`
    /// balls fall back to the ball bound, and an (fp-)empty dome clamps
    /// `d` to `−R`, which degrades gracefully to the nearest rim.
    /// Strictly tighter than `‖c‖ + R` exactly when the cut is active —
    /// the regime near convergence where the Hölder dome's half-space
    /// carries all the information.
    pub fn sup_norm(&self) -> f64 {
        let c_norm = linalg::norm2(&self.ball.center);
        let radius = self.ball.radius;
        let ball_sup = c_norm + radius;
        if self.half.is_degenerate() || radius < EPS {
            return ball_sup;
        }
        let d = self.cut_distance();
        if d >= radius {
            return ball_sup; // whole ball satisfies the cut
        }
        let c_g = linalg::dot(&self.half.g, &self.ball.center) / self.g_norm;
        if radius * c_g <= d * c_norm {
            return ball_sup; // farthest point satisfies the cut
        }
        let d = d.max(-radius);
        let rho = (radius * radius - d * d).max(0.0).sqrt();
        let c_perp = (c_norm * c_norm - c_g * c_g).max(0.0).sqrt();
        let along = c_g + d;
        let across = c_perp + rho;
        let rim = (along * along + across * across).sqrt();
        (rim * (1.0 + SUP_NORM_FP_MARGIN)).min(ball_sup)
    }

    /// `Rad(D)` (eq. 32): half the diameter of the dome.
    ///
    /// With cut distance `d` from the ball centre:
    /// * `d ≥ 0`  — the cap is at least a hemisphere; an antipodal pair
    ///   perpendicular to `g` survives, so `Rad = R`;
    /// * `−R < d < 0` — the widest chord is the cut disc: `√(R² − d²)`;
    /// * `d ≤ −R` — empty: `Rad = 0`.
    pub fn rad(&self) -> f64 {
        let radius = self.ball.radius;
        if self.half.is_degenerate() {
            return if self.half.delta >= 0.0 { radius } else { 0.0 };
        }
        let d = self.cut_distance();
        if d >= 0.0 {
            radius
        } else if d <= -radius {
            0.0
        } else {
            (radius * radius - d * d).max(0.0).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{Gen, Runner};

    fn random_dome(g: &mut Gen, m: usize) -> Dome {
        let c = g.vec_normal(m);
        let radius = g.f64_in(0.1, 2.0);
        let normal = g.vec_normal(m);
        // delta chosen so the cut passes within the ball most of the time
        let d = g.f64_in(-0.9, 0.9) * radius;
        let delta = linalg::dot(&normal, &c) + d * linalg::norm2(&normal);
        Dome::new(Ball::new(c, radius), HalfSpace::new(normal, delta))
    }

    #[test]
    fn max_inner_upper_bounds_samples() {
        Runner::new(31).cases(40).run("dome max bound", |g| {
            let m = g.usize_in(2, 10);
            let dome = random_dome(g, m);
            if dome.is_empty() {
                return Ok(());
            }
            let a = g.vec_normal(m);
            let bound = dome.max_inner(&a);
            let bound_abs = dome.max_abs_inner(&a);
            // rejection-sample the dome
            let mut found = 0;
            for _ in 0..400 {
                let mut u = g.rng().unit_ball(m);
                for (ui, ci) in u.iter_mut().zip(&dome.ball.center) {
                    *ui = ci + dome.ball.radius * *ui;
                }
                if dome.half.contains(&u, 0.0) {
                    found += 1;
                    let v = linalg::dot(&a, &u);
                    if v > bound + 1e-9 {
                        return Err(format!("sample {v} > bound {bound}"));
                    }
                    if v.abs() > bound_abs + 1e-9 {
                        return Err("abs bound violated".into());
                    }
                }
            }
            let _ = found;
            Ok(())
        });
    }

    #[test]
    fn max_inner_tight_for_hemisphere() {
        // When the cut passes exactly through the centre (psi2 = 0) and
        // a = g, the maximum is <a,c> (the maximizer is on the cut).
        let c = vec![0.0, 0.0];
        let g = vec![1.0, 0.0];
        let dome = Dome::new(
            Ball::new(c, 1.0),
            HalfSpace::new(g.clone(), 0.0),
        );
        assert!((dome.psi2() - 0.0).abs() < 1e-15);
        // max <g, u> over the half-disc {u: ||u||<=1, u_x <= 0} is 0.
        assert!(dome.max_inner(&g).abs() < 1e-12);
        // perpendicular direction is unrestricted: max = R
        assert!((dome.max_inner(&[0.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_cut_reduces_to_ball() {
        let mut g = Gen::for_case(3, 0);
        let c = g.vec_normal(5);
        let ball = Ball::new(c.clone(), 0.8);
        // delta far beyond the ball: psi2 = 1
        let normal = g.vec_normal(5);
        let delta = linalg::dot(&normal, &c)
            + 10.0 * linalg::norm2(&normal);
        let dome = Dome::new(ball, HalfSpace::new(normal, delta));
        assert_eq!(dome.psi2(), 1.0);
        let a = g.vec_normal(5);
        let ball2 = Ball::new(c, 0.8);
        assert!((dome.max_abs_inner(&a) - ball2.max_abs_inner(&a)).abs() < 1e-12);
        assert!((dome.rad() - 0.8).abs() < 1e-15);
    }

    #[test]
    fn rad_formula_cases() {
        let ball = Ball::new(vec![0.0, 0.0], 1.0);
        // d >= 0: Rad = R
        let d1 = Dome::new(ball.clone(), HalfSpace::new(vec![1.0, 0.0], 0.5));
        assert!((d1.rad() - 1.0).abs() < 1e-15);
        // d = -0.6: Rad = sqrt(1 - 0.36) = 0.8
        let d2 = Dome::new(ball.clone(), HalfSpace::new(vec![1.0, 0.0], -0.6));
        assert!((d2.rad() - 0.8).abs() < 1e-12);
        // d <= -R: empty
        let d3 = Dome::new(ball.clone(), HalfSpace::new(vec![1.0, 0.0], -1.5));
        assert!(d3.is_empty());
        assert_eq!(d3.rad(), 0.0);
    }

    #[test]
    fn rad_matches_sampled_diameter() {
        Runner::new(37).cases(25).run("rad vs sampled diameter", |g| {
            let m = g.usize_in(2, 6);
            let dome = random_dome(g, m);
            if dome.is_empty() {
                return Ok(());
            }
            let rad = dome.rad();
            // sample points, find max pairwise distance/2
            let mut pts: Vec<Vec<f64>> = Vec::new();
            for _ in 0..1500 {
                let mut u = g.rng().unit_ball(m);
                for (ui, ci) in u.iter_mut().zip(&dome.ball.center) {
                    *ui = ci + dome.ball.radius * *ui;
                }
                if dome.half.contains(&u, 0.0) {
                    pts.push(u);
                }
            }
            if pts.len() < 10 {
                return Ok(()); // sliver dome, sampling too sparse
            }
            let mut best: f64 = 0.0;
            for i in 0..pts.len() {
                for j in i + 1..pts.len() {
                    let mut d2 = 0.0;
                    for k in 0..m {
                        let dd = pts[i][k] - pts[j][k];
                        d2 += dd * dd;
                    }
                    best = best.max(d2.sqrt() / 2.0);
                }
            }
            // Sampled diameter is an inner approximation.
            if best > rad + 1e-9 {
                return Err(format!("sampled {best} > rad {rad}"));
            }
            if best < 0.5 * rad {
                return Err(format!("rad {rad} looks too large vs {best}"));
            }
            Ok(())
        });
    }

    #[test]
    fn sup_norm_hand_cases() {
        // Centered ball: every cut position still yields R (the rim is
        // a sphere of radius R around the origin).
        let d0 = Dome::new(
            Ball::new(vec![0.0, 0.0], 1.0),
            HalfSpace::new(vec![1.0, 0.0], 0.0),
        );
        assert!((d0.sup_norm() - 1.0).abs() < 1e-12);
        // Off-center, cut active: B((1,0), 1) ∩ {u_x ≤ 0.5}.  Farthest
        // ball point (2,0) violates; rim points (0.5, ±√0.75) have norm
        // exactly 1 — strictly below the ball bound 2.
        let d1 = Dome::new(
            Ball::new(vec![1.0, 0.0], 1.0),
            HalfSpace::new(vec![1.0, 0.0], 0.5),
        );
        assert!((d1.sup_norm() - 1.0).abs() < 1e-12);
        // Cut inactive (δ beyond the ball): bitwise the ball bound.
        let d2 = Dome::new(
            Ball::new(vec![1.0, 0.0], 1.0),
            HalfSpace::new(vec![1.0, 0.0], 5.0),
        );
        assert_eq!(d2.sup_norm().to_bits(), 2.0f64.to_bits());
        // Tangent from outside (d = −R): the rim degenerates to the
        // single point c − R·ĝ.
        let d3 = Dome::new(
            Ball::new(vec![2.0, 0.0], 1.0),
            HalfSpace::new(vec![1.0, 0.0], 1.0),
        );
        assert!((d3.sup_norm() - 1.0).abs() < 1e-10);
        // Radius 0: the point c, from either branch.
        let d4 = Dome::new(
            Ball::new(vec![3.0, 4.0], 0.0),
            HalfSpace::new(vec![1.0, 0.0], 0.0),
        );
        assert!((d4.sup_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sup_norm_dominates_samples_and_ball_bound() {
        Runner::new(41).cases(60).run("dome sup_norm bound", |g| {
            let m = g.usize_in(2, 10);
            let dome = random_dome(g, m);
            let sup = dome.sup_norm();
            let ball_sup =
                linalg::norm2(&dome.ball.center) + dome.ball.radius;
            if sup > ball_sup {
                return Err(format!(
                    "sup_norm {sup} exceeds ball bound {ball_sup}"
                ));
            }
            for _ in 0..300 {
                let mut u = g.rng().unit_ball(m);
                for (ui, ci) in u.iter_mut().zip(&dome.ball.center) {
                    *ui = ci + dome.ball.radius * *ui;
                }
                if dome.half.contains(&u, 0.0) {
                    let nu = linalg::norm2(&u);
                    if nu > sup + 1e-9 {
                        return Err(format!(
                            "member norm {nu} > sup_norm {sup}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sup_norm_is_attained_on_the_rim() {
        // When the cut is active the bound must be tight: the rim point
        // c + d·ĝ + ρ·ŵ (ŵ along c_⊥) is in the dome and attains it.
        Runner::new(43).cases(40).run("dome sup_norm attained", |g| {
            let m = g.usize_in(2, 8);
            let c = g.vec_normal(m);
            let radius = g.f64_in(0.1, 2.0);
            let normal = g.vec_normal(m);
            // force an active cut: d strictly inside (−R, R), on the
            // origin side of the center
            let d = g.f64_in(-0.9, 0.5) * radius;
            let gn = linalg::norm2(&normal);
            let delta = linalg::dot(&normal, &c) + d * gn;
            let dome = Dome::new(
                Ball::new(c.clone(), radius),
                HalfSpace::new(normal.clone(), delta),
            );
            let sup = dome.sup_norm();
            let c_norm = linalg::norm2(&c);
            let c_g = linalg::dot(&normal, &c) / gn;
            if radius * c_g <= d * c_norm {
                return Ok(()); // ball branch: attained at c(1 + R/‖c‖)
            }
            // build the rim maximizer explicitly
            let ghat: Vec<f64> = normal.iter().map(|v| v / gn).collect();
            let mut w: Vec<f64> = c
                .iter()
                .zip(&ghat)
                .map(|(ci, gi)| ci - c_g * gi)
                .collect();
            let wn = linalg::norm2(&w);
            if wn < 1e-9 {
                return Ok(()); // c ∥ g: any rim direction ties
            }
            for v in &mut w {
                *v /= wn;
            }
            let rho = (radius * radius - d * d).max(0.0).sqrt();
            let u: Vec<f64> = c
                .iter()
                .zip(&ghat)
                .zip(&w)
                .map(|((ci, gi), wi)| ci + d * gi + rho * wi)
                .collect();
            if !dome.contains(&u, 1e-9) {
                return Err("rim maximizer not in dome".into());
            }
            let nu = linalg::norm2(&u);
            if (nu - sup).abs() > 1e-9 * (1.0 + sup) {
                return Err(format!(
                    "sup_norm {sup} not attained: rim point norm {nu}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn contains_respects_both_constraints() {
        let dome = Dome::new(
            Ball::new(vec![0.0, 0.0], 1.0),
            HalfSpace::new(vec![0.0, 1.0], 0.0),
        );
        assert!(dome.contains(&[0.5, -0.5], 1e-12));
        assert!(!dome.contains(&[0.5, 0.5], 1e-12)); // violates cut
        assert!(!dome.contains(&[0.0, -1.5], 1e-12)); // outside ball
    }
}
