//! Safe-region geometry: balls, half-spaces and domes (§III-B), with the
//! closed-form screening maxima of eq. (11) and eq. (14)-(15) and the
//! region radius `Rad(·)` of eq. (32) used by Fig. 1.

pub mod ball;
pub mod dome;
pub mod halfspace;

pub use ball::Ball;
pub use dome::Dome;
pub use halfspace::HalfSpace;

/// Shared numerical guard (same value as the Python layer).
pub const EPS: f64 = 1e-12;

/// `f(ψ₁, ψ₂)` from eq. (15), clamped for numerical safety.
///
/// `f = 1` when ψ₁ ≤ ψ₂ (the ball maximizer already satisfies the cut),
/// else `ψ₁ψ₂ + √(1−ψ₁²)√(1−ψ₂²)` (the maximizer slides along the cut
/// circle).
#[inline]
pub fn f_dome(psi1: f64, psi2: f64) -> f64 {
    if psi1 <= psi2 {
        1.0
    } else {
        let s1 = (1.0 - psi1 * psi1).max(0.0).sqrt();
        let s2 = (1.0 - psi2 * psi2).max(0.0).sqrt();
        psi1 * psi2 + s1 * s2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f_dome_limits() {
        // psi1 <= psi2 → 1
        assert_eq!(f_dome(-0.5, 0.0), 1.0);
        assert_eq!(f_dome(1.0, 1.0), 1.0);
        // psi2 = 1 → always 1 (no effective cut)
        assert_eq!(f_dome(0.3, 1.0), 1.0);
        // psi1 = 1 > psi2 → f = psi2
        assert!((f_dome(1.0, 0.25) - 0.25).abs() < 1e-15);
        // antisymmetric pair at psi2 = -1: f = -psi1
        assert!((f_dome(0.6, -1.0) + 0.6).abs() < 1e-15);
    }

    #[test]
    fn f_dome_is_cosine_of_angle_difference() {
        // For psi1 > psi2: f = cos(acos(psi1) - acos(psi2))... actually
        // f = cos(theta1 - theta2) with cos(theta_i) = psi_i; check
        // against the trig identity on a grid.
        for &p1 in &[-0.9, -0.3, 0.2, 0.7, 0.95] {
            for &p2 in &[-0.95, -0.5, 0.0, 0.5, 0.9] {
                if p1 > p2 {
                    let want = ((p1 as f64).acos() - (p2 as f64).acos()).cos();
                    assert!((f_dome(p1, p2) - want).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn f_dome_bounded_by_one() {
        for &p1 in &[-1.0, -0.5, 0.0, 0.5, 1.0] {
            for &p2 in &[-1.0, -0.5, 0.0, 0.5, 1.0] {
                let f = f_dome(p1, p2);
                assert!(f <= 1.0 + 1e-15 && f >= -1.0 - 1e-15);
            }
        }
    }
}
